//! Durable prefix cache, end-to-end on the native backend: snapshot →
//! restart → warm hit with bitwise-identical completions and zero
//! upload; corrupted/truncated snapshots degrade to cold prefill (never
//! wrong tokens, never a panic); the spill tier demotes LRU victims to
//! disk and promotes them back checksum-verified.

use bifurcated_attn::coordinator::{
    Engine, EngineConfig, GenerationRequest, ModePolicy, SamplingParams,
};
use bifurcated_attn::corpus;
use bifurcated_attn::runtime::models::DecodeMode;

fn req(id: u64, prompt: &str, n: usize, seed: u64) -> GenerationRequest {
    GenerationRequest {
        id,
        prompt: prompt.into(),
        params: SamplingParams {
            n,
            temperature: 0.8,
            top_p: 0.95,
            max_tokens: 6,
            stop_token: Some(corpus::SEMI),
            seed,
            mode: None,
            deadline_ms: None,
        },
    }
}

fn texts(r: &bifurcated_attn::coordinator::RequestResult) -> Vec<String> {
    r.completions.iter().map(|c| c.text.clone()).collect()
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("bifattn-persist-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn warm_restart_reproduces_cold_with_zero_upload() {
    let prompt = "10+2=12;11+3=14;12+4=";
    let dir = tmpdir("restart");
    let mut cfg = EngineConfig::default();
    cfg.cache_dir = Some(dir.clone());
    let engine = Engine::native("pico-mq", 0, cfg.clone()).unwrap();
    let prompt_len = engine.tokenize_prompt(prompt).unwrap().len();
    let cold = engine.generate(&req(7, prompt, 8, 5)).unwrap();
    assert_eq!(cold.mode_used, DecodeMode::Bifurcated);
    assert!(cold.timing.upload_bytes > 0, "cold request uploads the context");
    engine.snapshot_now().unwrap();
    drop(engine);

    // "restart": a fresh engine over the same cache dir restores the node
    let engine2 = Engine::native("pico-mq", 0, cfg).unwrap();
    {
        let p = engine2.persist.borrow();
        let c = p.as_ref().unwrap().counters;
        assert_eq!(c.restore_nodes, 1, "one node restored");
        assert_eq!(c.restore_dropped, 0);
        assert_eq!(c.checksum_failures, 0);
        assert!(c.restore_bytes > 0);
    }
    let warm = engine2.generate(&req(7, prompt, 8, 5)).unwrap();
    assert_eq!(texts(&warm), texts(&cold), "restored node must reproduce cold bitwise");
    assert_eq!(warm.timing.cache_hit_tokens, prompt_len);
    assert_eq!(warm.timing.upload_bytes, 0, "warm restart skips the upload");
    let m = engine2.metrics_report();
    assert_eq!(m.req("persist").f64_of("restore_nodes"), 1.0);
    engine2.cache.borrow().check_invariants(&engine2.kv.borrow()).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_snapshot_record_degrades_to_cold_prefill() {
    let dir = tmpdir("corrupt");
    let mut cfg = EngineConfig::default();
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    cfg.cache_dir = Some(dir.clone());
    let engine = Engine::native("pico-mq", 0, cfg.clone()).unwrap();
    engine.generate(&req(1, "1+1=", 4, 2)).unwrap();
    let cold2 = engine.generate(&req(2, "2+2=", 4, 3)).unwrap();
    engine.snapshot_now().unwrap();
    drop(engine);

    // flip one payload byte in the second (last-written) record
    let snap = dir.join("snapshot.bin");
    let mut bytes = std::fs::read(&snap).unwrap();
    let n = bytes.len();
    bytes[n - 9] ^= 0x40;
    std::fs::write(&snap, &bytes).unwrap();

    let engine2 = Engine::native("pico-mq", 0, cfg).unwrap();
    {
        let p = engine2.persist.borrow();
        let c = p.as_ref().unwrap().counters;
        assert_eq!(c.restore_nodes, 1, "only the intact record restores");
        assert_eq!(c.restore_dropped, 1, "the flipped record is dropped, not trusted");
        assert_eq!(c.checksum_failures, 1);
    }
    // the survivor is warm, the corrupted prefix serves cold — and both
    // still produce exactly the completions a cold engine produces
    assert!(engine2.generate(&req(3, "1+1=", 4, 2)).unwrap().timing.cache_hit_tokens > 0);
    let redone = engine2.generate(&req(2, "2+2=", 4, 3)).unwrap();
    assert_eq!(redone.timing.cache_hit_tokens, 0, "corrupt record must not serve");
    assert_eq!(texts(&redone), texts(&cold2));
    let m = engine2.metrics_report();
    assert_eq!(m.req("persist").f64_of("checksum_failures"), 1.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_snapshot_drops_only_the_torn_tail() {
    let dir = tmpdir("truncate");
    let mut cfg = EngineConfig::default();
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    cfg.cache_dir = Some(dir.clone());
    let engine = Engine::native("pico-mq", 0, cfg.clone()).unwrap();
    engine.generate(&req(1, "1+1=", 4, 2)).unwrap();
    engine.generate(&req(2, "2+2=", 4, 3)).unwrap();
    engine.snapshot_now().unwrap();
    drop(engine);

    // simulate a torn write: the file ends mid-record
    let snap = dir.join("snapshot.bin");
    let mut bytes = std::fs::read(&snap).unwrap();
    let n = bytes.len();
    bytes.truncate(n - 5);
    std::fs::write(&snap, &bytes).unwrap();

    let engine2 = Engine::native("pico-mq", 0, cfg).unwrap();
    {
        let p = engine2.persist.borrow();
        let c = p.as_ref().unwrap().counters;
        assert_eq!(c.restore_nodes, 1);
        assert_eq!(c.restore_dropped, 1);
        assert_eq!(c.checksum_failures, 0, "a torn tail is not a checksum failure");
    }
    assert!(engine2.generate(&req(3, "1+1=", 4, 2)).unwrap().timing.cache_hit_tokens > 0);
    assert_eq!(engine2.generate(&req(4, "2+2=", 4, 3)).unwrap().timing.cache_hit_tokens, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spilled_node_promotes_back_bit_exact() {
    let dir = tmpdir("spill");
    let mut cfg = EngineConfig::default();
    cfg.prefix_cache_entries = 1;
    cfg.cache_dir = Some(dir.clone());
    cfg.spill_bytes = 64 << 20;
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    let engine = Engine::native("pico-mq", 0, cfg).unwrap();
    let prompt_len = engine.tokenize_prompt("1+1=").unwrap().len();

    let cold = engine.generate(&req(1, "1+1=", 4, 9)).unwrap();
    engine.generate(&req(2, "2+2=", 4, 10)).unwrap(); // evicts "1+1=" → spill
    {
        let p = engine.persist.borrow();
        let store = p.as_ref().unwrap();
        assert_eq!(store.counters.spills, 1);
        assert_eq!(store.spilled_entries(), 1);
        assert!(store.spilled_bytes() > 0);
    }

    // re-requesting the spilled prefix promotes it: full warm hit, no
    // upload accounted to the request, completions bitwise-identical
    let promoted = engine.generate(&req(1, "1+1=", 4, 9)).unwrap();
    assert_eq!(texts(&promoted), texts(&cold), "promotion must be bit-exact");
    assert_eq!(promoted.timing.cache_hit_tokens, prompt_len);
    assert_eq!(promoted.timing.upload_bytes, 0);
    {
        let p = engine.persist.borrow();
        let c = p.as_ref().unwrap().counters;
        assert_eq!(c.promotes, 1);
        assert_eq!(c.checksum_failures, 0);
        assert_eq!(c.spills, 2, "the promotion evicted+spilled the other node");
    }
    let m = engine.metrics_report();
    assert_eq!(m.req("persist").f64_of("promotes"), 1.0);
    engine.cache.borrow().check_invariants(&engine.kv.borrow()).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
