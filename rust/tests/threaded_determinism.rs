//! Threaded determinism: the kernel fan-out must never change results.
//!
//! Threads only partition independent output rows (each row's reduction
//! order is fixed inside a tile), so the same seed + the same request must
//! produce **bitwise-identical** completions at `--threads 1` and
//! `--threads 8` — token ids, text, and log-probabilities alike. This is
//! what makes the threading flag safe to default to all cores.

use bifurcated_attn::coordinator::{
    Engine, EngineConfig, GenerationRequest, ModePolicy, SamplingParams,
};
use bifurcated_attn::corpus;
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::NativeBackend;

fn engine_with_threads(threads: usize, policy: Option<ModePolicy>) -> Engine<NativeBackend> {
    let mut cfg = EngineConfig { threads, ..EngineConfig::default() };
    if let Some(p) = policy {
        cfg.scheduler.policy = p;
    }
    Engine::native("pico-mg", 0, cfg).unwrap()
}

fn req(seed: u64) -> GenerationRequest {
    GenerationRequest {
        id: 42,
        prompt: "10+2=12;11+3=14;12+4=".into(),
        params: SamplingParams {
            n: 8,
            temperature: 1.1,
            top_p: 0.95,
            max_tokens: 6,
            stop_token: Some(corpus::SEMI),
            seed,
            mode: None,
        },
    }
}

#[test]
fn same_seed_same_completions_across_thread_counts() {
    for mode in [DecodeMode::Bifurcated, DecodeMode::Fused] {
        let e1 = engine_with_threads(1, Some(ModePolicy::Force(mode)));
        let e8 = engine_with_threads(8, Some(ModePolicy::Force(mode)));
        assert_eq!(e1.rt.threads(), 1);
        assert_eq!(e8.rt.threads(), 8);
        let r1 = e1.generate(&req(13)).unwrap();
        let r8 = e8.generate(&req(13)).unwrap();
        assert_eq!(r1.completions.len(), r8.completions.len());
        for (a, b) in r1.completions.iter().zip(&r8.completions) {
            assert_eq!(a.tokens, b.tokens, "{mode:?}: token stream diverged across threads");
            assert_eq!(a.text, b.text);
            // bitwise: log-probs come out of the same float ops
            assert_eq!(a.sum_logp.to_bits(), b.sum_logp.to_bits(), "{mode:?}: logp drifted");
            assert_eq!(a.finished_by_stop, b.finished_by_stop);
        }
    }
}

#[test]
fn config_zero_threads_means_auto() {
    let auto = engine_with_threads(0, None);
    assert_eq!(auto.rt.threads(), bifurcated_attn::runtime::native::default_threads());
    assert!(auto.rt.threads() >= 1);
}

#[test]
fn warm_cache_hits_are_thread_count_invariant() {
    // prefill_extend and cached-context decode run the same row-parallel
    // kernels; a warm hit at 8 threads must reproduce a cold run at 1.
    let e1 = engine_with_threads(1, Some(ModePolicy::Force(DecodeMode::Bifurcated)));
    let e8 = engine_with_threads(8, Some(ModePolicy::Force(DecodeMode::Bifurcated)));
    let cold = e1.generate(&req(5)).unwrap();
    e8.generate(&req(5)).unwrap(); // populate e8's cache
    let warm = e8.generate(&req(5)).unwrap();
    assert_eq!(warm.timing.upload_bytes, 0, "second identical request is a full hit");
    for (a, b) in cold.completions.iter().zip(&warm.completions) {
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.sum_logp.to_bits(), b.sum_logp.to_bits());
    }
}
