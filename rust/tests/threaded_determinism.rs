//! Threaded determinism + pool lifecycle: the kernel fan-out must never
//! change results, and the persistent worker pool must survive the whole
//! serving lifecycle.
//!
//! Executors only partition independent output rows (each row's reduction
//! order is fixed inside a tile), so the same seed + the same request
//! must produce **bitwise-identical** completions at every pool size —
//! token ids, text, and log-probabilities alike — and under the
//! scoped-spawn reference dispatch. This is what makes the threading flag
//! safe to default to all cores. The lifecycle tests pin the pool's
//! clean-shutdown and reuse guarantees: one pool serves
//! prefill → decode → prefill across requests, and dropping a backend
//! (pool included) joins its workers whether they are parked, spinning,
//! or have never run a job.

use bifurcated_attn::coordinator::{
    Engine, EngineConfig, GenerationRequest, ModePolicy, SamplingParams,
};
use bifurcated_attn::corpus;
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::native::WorkerPool;
use bifurcated_attn::runtime::NativeBackend;

fn engine_with_threads(threads: usize, policy: Option<ModePolicy>) -> Engine<NativeBackend> {
    let mut cfg = EngineConfig { threads, ..EngineConfig::default() };
    if let Some(p) = policy {
        cfg.scheduler.policy = p;
    }
    Engine::native("pico-mg", 0, cfg).unwrap()
}

fn req(seed: u64) -> GenerationRequest {
    GenerationRequest {
        id: 42,
        prompt: "10+2=12;11+3=14;12+4=".into(),
        params: SamplingParams {
            n: 8,
            temperature: 1.1,
            top_p: 0.95,
            max_tokens: 6,
            stop_token: Some(corpus::SEMI),
            seed,
            mode: None,
            deadline_ms: None,
        },
    }
}

#[test]
fn same_seed_same_completions_across_pool_sizes() {
    // Pool sizes {1, 2, 8}: size 1 is the no-pool serial dispatcher, 2 is
    // the minimal real pool, 8 oversubscribes a small CI box — all three
    // must agree bitwise, in both decode modes.
    for mode in [DecodeMode::Bifurcated, DecodeMode::Fused] {
        let e1 = engine_with_threads(1, Some(ModePolicy::Force(mode)));
        let r1 = e1.generate(&req(13)).unwrap();
        for threads in [2usize, 8] {
            let en = engine_with_threads(threads, Some(ModePolicy::Force(mode)));
            assert_eq!(en.rt.threads(), threads);
            let rn = en.generate(&req(13)).unwrap();
            assert_eq!(r1.completions.len(), rn.completions.len());
            for (a, b) in r1.completions.iter().zip(&rn.completions) {
                assert_eq!(
                    a.tokens, b.tokens,
                    "{mode:?}: token stream diverged at pool size {threads}"
                );
                assert_eq!(a.text, b.text);
                // bitwise: log-probs come out of the same float ops
                assert_eq!(
                    a.sum_logp.to_bits(),
                    b.sum_logp.to_bits(),
                    "{mode:?}: logp drifted at pool size {threads}"
                );
                assert_eq!(a.finished_by_stop, b.finished_by_stop);
            }
        }
    }
}

#[test]
fn scoped_reference_dispatch_reproduces_pool_completions() {
    // The spawn-vs-pool bench ablation is a fair A/B only if the two
    // dispatchers are bit-for-bit interchangeable end to end.
    let pool = engine_with_threads(4, Some(ModePolicy::Force(DecodeMode::Bifurcated)));
    let be = NativeBackend::preset("pico-mg", 0).unwrap().with_threads(4).with_reference_dispatch();
    let mut cfg = EngineConfig { threads: 4, ..EngineConfig::default() };
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    let scoped = Engine::new(bifurcated_attn::runtime::TokenizerInfo::builtin(), be, cfg);
    let rp = pool.generate(&req(21)).unwrap();
    let rs = scoped.generate(&req(21)).unwrap();
    for (a, b) in rp.completions.iter().zip(&rs.completions) {
        assert_eq!(a.tokens, b.tokens, "dispatcher changed the token stream");
        assert_eq!(a.sum_logp.to_bits(), b.sum_logp.to_bits());
    }
}

#[test]
fn config_zero_threads_means_auto() {
    let auto = engine_with_threads(0, None);
    assert_eq!(auto.rt.threads(), bifurcated_attn::runtime::native::default_threads());
    assert!(auto.rt.threads() >= 1);
}

#[test]
fn warm_cache_hits_are_thread_count_invariant() {
    // prefill_extend and cached-context decode run the same row-parallel
    // kernels; a warm hit at 8 threads must reproduce a cold run at 1.
    let e1 = engine_with_threads(1, Some(ModePolicy::Force(DecodeMode::Bifurcated)));
    let e8 = engine_with_threads(8, Some(ModePolicy::Force(DecodeMode::Bifurcated)));
    let cold = e1.generate(&req(5)).unwrap();
    e8.generate(&req(5)).unwrap(); // populate e8's cache
    let warm = e8.generate(&req(5)).unwrap();
    assert_eq!(warm.timing.upload_bytes, 0, "second identical request is a full hit");
    for (a, b) in cold.completions.iter().zip(&warm.completions) {
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.sum_logp.to_bits(), b.sum_logp.to_bits());
    }
}

#[test]
fn one_pool_serves_prefill_decode_prefill_across_requests() {
    // The backend builds ONE pool and reuses it for every phase of every
    // request. Interleave cold prefills, batched decode waves, and warm
    // extends on the same engine, then check against a fresh engine —
    // reuse must not corrupt anything.
    let e = engine_with_threads(4, Some(ModePolicy::Force(DecodeMode::Bifurcated)));
    let a1 = e.generate(&req(9)).unwrap(); // prefill + decode
    let mut longer = req(9);
    longer.prompt.push_str("16;13+5="); // partial hit -> extend + decode
    let a2 = e.generate(&longer).unwrap();
    let a3 = e.generate(&req(9)).unwrap(); // warm full hit -> decode only
    let fresh = engine_with_threads(4, Some(ModePolicy::Force(DecodeMode::Bifurcated)));
    let b1 = fresh.generate(&req(9)).unwrap();
    for (a, b) in a1.completions.iter().zip(&b1.completions) {
        assert_eq!(a.tokens, b.tokens, "pool reuse changed a cold completion");
        assert_eq!(a.sum_logp.to_bits(), b.sum_logp.to_bits());
    }
    // warm completions reproduce the cold ones (same engine, pool reused)
    for (a, b) in a1.completions.iter().zip(&a3.completions) {
        assert_eq!(a.tokens, b.tokens, "pool reuse changed a warm completion");
    }
    assert!(a2.completions.iter().all(|c| !c.tokens.is_empty()));
}

#[test]
fn backend_drop_joins_pool_in_every_state() {
    // Never ran a job: workers were never even spawned (lazy pool).
    drop(NativeBackend::preset("pico-mq", 0).unwrap().with_threads(4));
    // Dropped right after heavy use: workers are mid-spin.
    let be = NativeBackend::preset("pico-mq", 0).unwrap().with_threads(4);
    let pre = be.prefill(&[1, 3, 12, 4]).unwrap();
    drop(be);
    assert!(pre.logits.iter().all(|v| v.is_finite()));
    // with_threads rebuilds the pool: the old one must shut down cleanly
    // while the new one takes over mid-lifecycle.
    let be = NativeBackend::preset("pico-mq", 0).unwrap().with_threads(2);
    let p2 = be.prefill(&[1, 3, 12, 4]).unwrap();
    let be = be.with_threads(8);
    let p8 = be.prefill(&[1, 3, 12, 4]).unwrap();
    assert_eq!(p2.logits, p8.logits);
}

#[test]
fn raw_pool_survives_queued_burst_then_drop() {
    // Hammer the pool with back-to-back jobs (the decode dispatch
    // pattern), then drop it immediately, workers still hot.
    use std::sync::atomic::{AtomicUsize, Ordering};
    let pool = WorkerPool::new(8);
    let total = AtomicUsize::new(0);
    for _ in 0..500 {
        pool.run(8, &|i| {
            total.fetch_add(i, Ordering::Relaxed);
        });
    }
    assert_eq!(total.load(Ordering::Relaxed), 500 * 28);
    drop(pool);
}
