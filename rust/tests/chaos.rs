//! Deterministic chaos suite: named failpoints (`util::failpoint`) inject
//! prefill OOM, decode errors, panics, and slow steps at exact hit counts
//! so every overload/fault path is exercised on the real engine:
//!
//! * a faulting lane retires with a typed [`WaveFault`] while co-batched
//!   survivors finish **bitwise-identical** to an undisturbed solo run;
//! * deadline expiry retires a request at the next step boundary with a
//!   typed [`DeadlineExceeded`], again without perturbing survivors;
//! * graceful drain finishes in-flight waves and 503s parked requests;
//! * after every injected fault the KV manager holds zero sequences and
//!   the engine keeps serving.
//!
//! The registry is thread-local and the batcher runs on the test thread
//! (via `ScriptedSource`), so parallel tests cannot perturb each other.
//! CI re-runs this suite with ambient `BIFURCATED_FAILPOINTS` specs; every
//! test arms its own points with `set()` (which replaces the env config)
//! except the ambient test at the bottom, which deliberately honors it.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::Arc;

use bifurcated_attn::coordinator::batcher::{BatchConfig, BatchJob, Batcher, ScriptedSource};
use bifurcated_attn::coordinator::{
    AdmissionGate, DeadlineExceeded, Engine, EngineConfig, GenerationRequest, ModePolicy,
    RequestResult, SamplingParams, ShuttingDown, WaveFault,
};
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::NativeBackend;
use bifurcated_attn::util::failpoint;

fn engine() -> Engine<NativeBackend> {
    Engine::native("pico-mq", 0, EngineConfig::default()).unwrap()
}

fn req(id: u64, prompt: &str, n: usize, max_tokens: usize) -> GenerationRequest {
    GenerationRequest {
        id,
        prompt: prompt.into(),
        params: SamplingParams {
            n,
            temperature: 0.8,
            top_p: 0.95,
            max_tokens,
            stop_token: None,
            seed: id,
            mode: Some(ModePolicy::Force(DecodeMode::Bifurcated)),
            deadline_ms: None,
        },
    }
}

/// Run a set of scripted jobs through one batcher on this thread; replies
/// come back keyed by request id.
fn run_jobs(
    e: &Engine<NativeBackend>,
    jobs: Vec<(usize, GenerationRequest)>,
    gate: Option<Arc<AdmissionGate>>,
) -> BTreeMap<u64, anyhow::Result<RequestResult>> {
    let out: Rc<RefCell<BTreeMap<u64, anyhow::Result<RequestResult>>>> =
        Rc::new(RefCell::new(BTreeMap::new()));
    let mut src: ScriptedSource<NativeBackend> = ScriptedSource::new();
    for (at, r) in jobs {
        let sink = Rc::clone(&out);
        let id = r.id;
        src.push(
            at,
            BatchJob::Generate(
                r,
                None,
                Box::new(move |res| {
                    sink.borrow_mut().insert(id, res);
                }),
            ),
        );
    }
    let mut b = Batcher::new(e, BatchConfig { window_us: 0, max_wave_rows: 0 });
    if let Some(g) = gate {
        b = b.with_gate(g);
    }
    b.run(&mut src);
    Rc::try_unwrap(out).ok().expect("sink still shared").into_inner()
}

fn run_one(e: &Engine<NativeBackend>, r: GenerationRequest) -> anyhow::Result<RequestResult> {
    let id = r.id;
    run_jobs(e, vec![(0, r)], None).remove(&id).expect("no reply")
}

/// The co-batched survivor's tokens must be bitwise what an undisturbed
/// solo run of the same request produces on a fresh engine.
fn assert_bitwise_solo(survivor: &RequestResult, original: GenerationRequest) {
    failpoint::clear();
    let solo = run_one(&engine(), original).expect("undisturbed solo run");
    assert_eq!(
        survivor.completions, solo.completions,
        "survivor must be bitwise-identical to an undisturbed run"
    );
}

fn assert_clean(e: &Engine<NativeBackend>) {
    e.kv.borrow().check_invariants().unwrap();
    e.cache.borrow().check_invariants(&e.kv.borrow()).unwrap();
    let st = e.kv.borrow().stats();
    assert_eq!(st.sequences, 0, "all leases returned");
    assert_eq!(st.contexts, st.cached_contexts, "no active context leaked");
}

const PREFIX: &str = "10+2=12;11+3=14;12+4=";

#[test]
fn prefill_oom_failpoint_rolls_back_pins() {
    failpoint::set("prefill_oom=1@1");
    let e = engine();
    let err = run_one(&e, req(1, PREFIX, 2, 4)).unwrap_err();
    assert!(format!("{err:#}").contains("failpoint prefill_oom injected"), "{err:#}");
    failpoint::clear();
    assert_clean(&e);
    // the engine keeps serving after the injected failure
    let ok = run_one(&e, req(2, PREFIX, 2, 4)).unwrap();
    assert_eq!(ok.completions.len(), 2);
    assert_clean(&e);
}

#[test]
fn decode_err_retires_one_lane_and_survivors_match_solo_bitwise() {
    // Two requests coalesce into one wave. `decode_err=2@2` fires on the
    // 2nd union step AND the first isolated retry, so lane 0 (request 1)
    // is the deterministic victim while request 2 survives containment.
    failpoint::set("decode_err=2@2");
    let e = engine();
    let jobs = vec![(0, req(1, PREFIX, 2, 4)), (0, req(2, PREFIX, 2, 4))];
    let mut replies = run_jobs(&e, jobs, None);
    let victim = replies.remove(&1).unwrap().unwrap_err();
    let survivor = replies.remove(&2).unwrap().expect("co-batched survivor must finish");
    assert!(victim.downcast_ref::<WaveFault>().is_some(), "typed WaveFault: {victim:#}");
    assert!(format!("{victim:#}").contains("failpoint decode_err injected"), "{victim:#}");
    assert!(survivor.timing.coalesced_peak_rows >= 4, "the two requests shared a wave");
    assert_eq!(e.metrics.contained_wave_steps(), 1);
    assert_eq!(e.metrics.wave_faults(), 1);
    assert_clean(&e);
    assert_bitwise_solo(&survivor, req(2, PREFIX, 2, 4));
    // the engine keeps serving
    assert_eq!(run_one(&e, req(3, PREFIX, 2, 4)).unwrap().completions.len(), 2);
}

#[test]
fn decode_panic_is_contained_per_lane() {
    // Same victim geometry as decode_err, but the union step *panics*:
    // catch_unwind at the innermost decode converts it to a WaveFault and
    // co-batched survivors still finish bitwise-clean.
    failpoint::set("decode_panic=2@2");
    let e = engine();
    let jobs = vec![(0, req(1, PREFIX, 2, 4)), (0, req(2, PREFIX, 2, 4))];
    let mut replies = run_jobs(&e, jobs, None);
    let victim = replies.remove(&1).unwrap().unwrap_err();
    let survivor = replies.remove(&2).unwrap().expect("survivor must outlive the panic");
    assert!(victim.downcast_ref::<WaveFault>().is_some(), "typed WaveFault: {victim:#}");
    assert!(format!("{victim:#}").contains("panic"), "{victim:#}");
    assert_eq!(e.metrics.contained_wave_steps(), 1);
    assert_eq!(e.metrics.wave_faults(), 1);
    assert_clean(&e);
    assert_bitwise_solo(&survivor, req(2, PREFIX, 2, 4));
    assert_eq!(run_one(&e, req(3, PREFIX, 2, 4)).unwrap().completions.len(), 2);
}

#[test]
fn all_lanes_faulting_closes_the_wave_cleanly() {
    // `decode_err=3@1` kills the union step and both isolated retries:
    // every lane retires, the wave closes, and the engine keeps serving.
    failpoint::set("decode_err=3@1");
    let e = engine();
    let replies = run_jobs(&e, vec![(0, req(1, PREFIX, 2, 4)), (0, req(2, PREFIX, 2, 4))], None);
    for (id, res) in replies {
        let err = res.unwrap_err();
        assert!(err.downcast_ref::<WaveFault>().is_some(), "req {id}: {err:#}");
    }
    assert_eq!(e.metrics.wave_faults(), 2);
    assert_clean(&e);
    failpoint::clear();
    assert_eq!(run_one(&e, req(3, PREFIX, 2, 4)).unwrap().completions.len(), 2);
}

#[test]
fn deadline_expires_at_a_step_boundary_without_disturbing_survivors() {
    // Every decode step sleeps 200 ms; request 1's 150 ms deadline blows
    // during the first step and the sweep retires it at the next boundary
    // (the budget comfortably covers prefill, so it dies holding a lane).
    failpoint::set("decode_slow=*@1:200");
    let e = engine();
    let mut slow = req(1, PREFIX, 2, 4);
    slow.params.deadline_ms = Some(150);
    let mut replies = run_jobs(&e, vec![(0, slow), (0, req(2, PREFIX, 2, 4))], None);
    let expired = replies.remove(&1).unwrap().unwrap_err();
    let survivor = replies.remove(&2).unwrap().expect("survivor must finish");
    let d = expired
        .downcast_ref::<DeadlineExceeded>()
        .unwrap_or_else(|| panic!("typed DeadlineExceeded: {expired:#}"));
    assert!(d.elapsed_ms >= 150, "expired after its budget: {d:?}");
    assert_eq!(d.freed_rows, 2, "both sampler rows released");
    assert_eq!(e.metrics.deadline_expired(), 1);
    assert_clean(&e);
    assert_bitwise_solo(&survivor, req(2, PREFIX, 2, 4));
}

#[test]
fn unmeetable_deadline_is_rejected_at_admission() {
    failpoint::clear();
    let e = engine();
    let mut r = req(1, PREFIX, 2, 4);
    r.params.deadline_ms = Some(0);
    let err = run_one(&e, r).unwrap_err();
    let d = err
        .downcast_ref::<DeadlineExceeded>()
        .unwrap_or_else(|| panic!("typed DeadlineExceeded: {err:#}"));
    assert_eq!(d.elapsed_ms, 0, "rejected before any work");
    assert_clean(&e);
}

#[test]
fn drain_finishes_active_wave_and_503s_parked_requests() {
    failpoint::clear();
    let e = engine();
    let gate = AdmissionGate::new();
    gate.configure(0, 0.0, 0.0, 5_000);
    let out: Rc<RefCell<BTreeMap<u64, anyhow::Result<RequestResult>>>> =
        Rc::new(RefCell::new(BTreeMap::new()));
    let mut src: ScriptedSource<NativeBackend> = ScriptedSource::new();
    // Poll release points: jobs at 0 land on the first poll, so request 2
    // (different prefix) arrives one scheduling tick after request 1's
    // wave launched, and the drain begins one tick after that.
    for (at, r) in [(0usize, req(1, PREFIX, 2, 8)), (2, req(2, "20+3=23;21+4=25;22+5=", 2, 8))] {
        let sink = Rc::clone(&out);
        let id = r.id;
        src.push(
            at,
            BatchJob::Generate(
                r,
                None,
                Box::new(move |res| {
                    sink.borrow_mut().insert(id, res);
                }),
            ),
        );
    }
    // The drain, begun between steps while request 1's wave is in
    // flight, must finish that wave and fail only the parked request.
    let drain_gate = Arc::clone(&gate);
    src.push(
        3,
        BatchJob::Inspect(Box::new(move |_e: &Engine<NativeBackend>| {
            drain_gate.begin_drain();
        })),
    );
    Batcher::new(&e, BatchConfig { window_us: 0, max_wave_rows: 0 })
        .with_gate(Arc::clone(&gate))
        .run(&mut src);
    let mut replies = Rc::try_unwrap(out).ok().expect("sink still shared").into_inner();
    let served = replies.remove(&1).unwrap().expect("in-flight wave must finish draining");
    assert_eq!(served.completions.len(), 2);
    let parked = replies.remove(&2).unwrap().unwrap_err();
    assert!(parked.downcast_ref::<ShuttingDown>().is_some(), "typed ShuttingDown: {parked:#}");
    assert_clean(&e);
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bifattn-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn durable_engine(dir: &std::path::Path, entries: usize, spill: usize) -> Engine<NativeBackend> {
    let mut cfg = EngineConfig::default();
    cfg.prefix_cache_entries = entries;
    cfg.cache_dir = Some(dir.to_path_buf());
    cfg.spill_bytes = spill;
    Engine::native("pico-mq", 0, cfg).unwrap()
}

#[test]
fn kill_mid_snapshot_preserves_the_prior_image() {
    failpoint::clear();
    let dir = tmpdir("midsnap");
    let e = durable_engine(&dir, 16, 0);
    e.generate(&req(1, "1+1=", 2, 4)).unwrap();
    e.snapshot_now().unwrap(); // durable image v1: one node
    e.generate(&req(2, "2+2=", 2, 4)).unwrap();

    // "kill" the next commit after the temp write but before the atomic
    // rename — exactly the torn-commit window a SIGKILL would hit
    failpoint::set("snap_write_err=1@1");
    let err = e.snapshot_now().unwrap_err();
    assert!(format!("{err:#}").contains("failpoint snap_write_err injected"), "{err:#}");
    failpoint::clear();
    drop(e);

    // the prior image survives untouched: only the v1 node restores, and
    // the stray .tmp from the failed commit is swept on reopen
    let e2 = durable_engine(&dir, 16, 0);
    assert_eq!(e2.persist.borrow().as_ref().unwrap().counters.restore_nodes, 1);
    assert!(e2.generate(&req(3, "1+1=", 2, 4)).unwrap().timing.cache_hit_tokens > 0);
    assert_eq!(e2.generate(&req(4, "2+2=", 2, 4)).unwrap().timing.cache_hit_tokens, 0);
    let leftover_tmp = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|f| f.ok())
        .any(|f| f.file_name().to_string_lossy().ends_with(".tmp"));
    assert!(!leftover_tmp, "torn commit temp file must be swept");
    assert_clean(&e2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn snap_read_corrupt_drops_one_record_and_serves_cold() {
    failpoint::clear();
    let dir = tmpdir("readcorrupt");
    let e = durable_engine(&dir, 16, 0);
    e.generate(&req(1, "1+1=", 2, 4)).unwrap();
    e.generate(&req(2, "2+2=", 2, 4)).unwrap();
    e.snapshot_now().unwrap();
    drop(e);

    // restore treats the first record as checksum-mismatched: it is
    // dropped (counted), the second restores, nothing panics or errors
    failpoint::set("snap_read_corrupt=1@1");
    let e2 = durable_engine(&dir, 16, 0);
    failpoint::clear();
    {
        let p = e2.persist.borrow();
        let c = p.as_ref().unwrap().counters;
        assert_eq!(c.restore_nodes, 1);
        assert_eq!(c.restore_dropped, 1);
        assert_eq!(c.checksum_failures, 1);
    }
    assert_eq!(e2.generate(&req(3, "1+1=", 2, 4)).unwrap().timing.cache_hit_tokens, 0);
    assert!(e2.generate(&req(4, "2+2=", 2, 4)).unwrap().timing.cache_hit_tokens > 0);
    assert_clean(&e2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spill_io_err_drops_the_entry_cleanly() {
    failpoint::clear();
    let dir = tmpdir("spillerr");
    let e = durable_engine(&dir, 1, 64 << 20);
    e.generate(&req(1, "1+1=", 2, 4)).unwrap();

    // the second prompt evicts the first; its demotion to disk fails —
    // the entry is dropped (old behavior), never half-written
    failpoint::set("spill_io_err=1@1");
    e.generate(&req(2, "2+2=", 2, 4)).unwrap();
    failpoint::clear();
    {
        let p = e.persist.borrow();
        let store = p.as_ref().unwrap();
        assert_eq!(store.counters.spill_errors, 1);
        assert_eq!(store.counters.spills, 0);
        assert_eq!(store.spilled_entries(), 0, "failed spill leaves no index entry");
    }
    // no disk copy: the first prompt is simply cold again; the resident
    // cache and KV accounting are unperturbed
    let redo = e.generate(&req(3, "1+1=", 2, 4)).unwrap();
    assert_eq!(redo.timing.cache_hit_tokens, 0);
    assert_eq!(redo.completions.len(), 2);
    assert_clean(&e);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ambient_env_failpoints_do_not_break_engine_hygiene() {
    // Deliberately does NOT clear the registry: whatever spec CI put in
    // $BIFURCATED_FAILPOINTS is honored. With points armed, success or
    // failure are both acceptable — leaked state is not. With nothing
    // armed, the request must simply succeed.
    let ambient = std::env::var(failpoint::ENV_VAR).is_ok();
    let e = engine();
    match run_one(&e, req(91, PREFIX, 2, 4)) {
        Ok(res) => assert_eq!(res.completions.len(), 2),
        Err(err) => assert!(ambient, "clean request failed with nothing armed: {err:#}"),
    }
    assert_clean(&e);
    // Disarmed, the same engine serves unconditionally.
    failpoint::clear();
    assert_eq!(run_one(&e, req(92, PREFIX, 2, 4)).unwrap().completions.len(), 2);
}
