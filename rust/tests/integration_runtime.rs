//! Integration: artifacts -> PJRT compile -> prefill/decode round-trips.
//!
//! Requires a `--features pjrt` build plus `make artifacts`. These tests
//! exercise the full AOT bridge: manifest parsing, weight loading,
//! HLO-text compilation, execution, and the paper's exactness claim
//! measured *end-to-end across the language boundary* (bifurcated vs
//! fused decode executables agree bitwise-ish). The artifact-free
//! equivalent on the native backend is tests/parity_native.rs.

#![cfg(feature = "pjrt")]

use bifurcated_attn::runtime::{
    cpu_client, DecodeMode, Manifest, ModelRuntime,
};

fn artifacts_root() -> std::path::PathBuf {
    // tests run from the workspace root
    let p = Manifest::default_root();
    assert!(
        p.join("manifest.json").exists(),
        "artifacts/manifest.json missing — run `make artifacts`"
    );
    p
}

fn encode_prompt(man: &Manifest, prompt: &str) -> Vec<i32> {
    let mut ids = vec![man.tokenizer.bos];
    ids.extend(man.tokenizer.encode(prompt).unwrap());
    ids
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

#[test]
fn manifest_loads_and_is_complete() {
    let man = Manifest::load(&artifacts_root()).unwrap();
    assert_eq!(man.tokenizer.vocab_size, 16);
    assert_eq!(man.serving.len(), 3, "pico mh/mg/mq");
    let names: Vec<_> = man.serving.iter().map(|e| e.name.as_str()).collect();
    assert!(names.contains(&"pico-mh") && names.contains(&"pico-mq"));
    for e in &man.serving {
        assert!(e.weights_bin.exists(), "{:?}", e.weights_bin);
        assert!(e.prefill.file.exists());
        for byb in e.decode.values() {
            for d in byb.values() {
                assert!(d.file.exists(), "{:?}", d.file);
            }
        }
        // attention-kind consistency
        match e.cfg.g {
            1 => assert_eq!(e.cfg.attention_kind, "multi_query"),
            g if g == e.cfg.h => assert_eq!(e.cfg.attention_kind, "multi_head"),
            _ => assert_eq!(e.cfg.attention_kind, "multi_group"),
        }
    }
    assert!(man.scaling.len() >= 3);
}

#[test]
fn tokenizer_roundtrip_via_manifest() {
    let man = Manifest::load(&artifacts_root()).unwrap();
    let ids = man.tokenizer.encode("12+7=19;").unwrap();
    assert_eq!(man.tokenizer.decode(&ids), "12+7=19;");
}

#[test]
fn prefill_decode_roundtrip_and_exactness() {
    let man = Manifest::load(&artifacts_root()).unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&man, &client, "pico-mq").unwrap();

    let prompt = encode_prompt(&man, "3+4=7;2+5=7;1+2=");
    let pre = rt.prefill(&prompt).unwrap();
    assert_eq!(pre.logits.len(), rt.cfg.vocab);
    assert!(pre.logits.iter().all(|x| x.is_finite()));
    assert_eq!(pre.kc.shape, vec![rt.cfg.l, rt.cfg.g, rt.cfg.m_c_max, rt.cfg.k]);

    // The model should strongly favor '3' (=1+2) after training.
    let three = *man.tokenizer.char_to_id.get(&'3').unwrap() as usize;
    assert_eq!(argmax(&pre.logits), three, "trained model should answer 1+2=3");

    // --- exactness: bifurcated vs fused decode executables, 3 steps ---
    let bucket = 2usize;
    let b = 2usize;
    let ctx_bif = rt.upload_context(&pre.kc, &pre.vc, prompt.len()).unwrap();
    // fused: replicate context per batch row -> [l, b, g, mc, k]
    let kc_rep = pre.kc.broadcast_at(1, bucket);
    let vc_rep = pre.vc.broadcast_at(1, bucket);
    let ctx_fus = rt.upload_context(&kc_rep, &vc_rep, prompt.len()).unwrap();
    assert!(ctx_fus.bytes > ctx_bif.bytes, "fused context upload must be b x larger");

    let (mut kd_b, mut vd_b) = rt.zero_decode_cache(bucket);
    let (mut kd_f, mut vd_f) = rt.zero_decode_cache(bucket);
    let mut toks = vec![three as i32; b];
    for step in 0..3 {
        let ob = rt
            .decode(DecodeMode::Bifurcated, bucket, &toks, step, &ctx_bif, &kd_b, &vd_b)
            .unwrap();
        let of = rt
            .decode(DecodeMode::Fused, bucket, &toks, step, &ctx_fus, &kd_f, &vd_f)
            .unwrap();
        assert_eq!(ob.logits.shape, vec![bucket, rt.cfg.vocab]);
        let lb = ob.logits.f32s();
        let lf = of.logits.f32s();
        let max_diff = lb
            .iter()
            .zip(lf)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 1e-4, "step {step}: bifurcated vs fused logits differ by {max_diff}");
        // identical rows for identical sampler states
        let row0 = &lb[..rt.cfg.vocab];
        let row1 = &lb[rt.cfg.vocab..2 * rt.cfg.vocab];
        for (a, b) in row0.iter().zip(row1) {
            assert!((a - b).abs() < 1e-4);
        }
        // greedy-feed the argmax back in
        toks = vec![argmax(row0) as i32; b];
        kd_b = ob.kd;
        vd_b = ob.vd;
        kd_f = of.kd;
        vd_f = of.vd;
    }
}

#[test]
fn greedy_decode_solves_arithmetic() {
    // End-to-end generation through the rust runtime: the trained pico-mq
    // model answers an in-distribution prompt correctly under greedy.
    let man = Manifest::load(&artifacts_root()).unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&man, &client, "pico-mq").unwrap();

    let prompt = encode_prompt(&man, "5+3=8;10+2=12;4+4=");
    let pre = rt.prefill(&prompt).unwrap();
    let ctx = rt.upload_context(&pre.kc, &pre.vc, prompt.len()).unwrap();
    let bucket = 1usize;
    let (mut kd, mut vd) = rt.zero_decode_cache(bucket);

    let mut out = String::new();
    let mut next = argmax(&pre.logits) as i32;
    for step in 0..6 {
        out.push_str(&man.tokenizer.decode(&[next]));
        if next == man.tokenizer.semicolon {
            break;
        }
        let o = rt
            .decode(DecodeMode::Bifurcated, bucket, &[next], step, &ctx, &kd, &vd)
            .unwrap();
        next = argmax(&o.logits.f32s()[..rt.cfg.vocab]) as i32;
        kd = o.kd;
        vd = o.vd;
    }
    assert!(
        out.starts_with("8;"),
        "expected greedy completion '8;' for 4+4=, got {out:?}"
    );
}

#[test]
fn padded_batch_rows_are_inert() {
    // Engine pads live batches up to the bucket; padding must not change
    // live rows. Run b=1 real tokens in a bucket of 4 vs bucket of 1.
    let man = Manifest::load(&artifacts_root()).unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&man, &client, "pico-mq").unwrap();
    let prompt = encode_prompt(&man, "2+2=");
    let pre = rt.prefill(&prompt).unwrap();
    let ctx = rt.upload_context(&pre.kc, &pre.vc, prompt.len()).unwrap();

    let tok = man.tokenizer.encode("4").unwrap();
    let (kd1, vd1) = rt.zero_decode_cache(1);
    let o1 = rt
        .decode(DecodeMode::Bifurcated, 1, &tok, 0, &ctx, &kd1, &vd1)
        .unwrap();
    let (kd4, vd4) = rt.zero_decode_cache(4);
    let o4 = rt
        .decode(DecodeMode::Bifurcated, 4, &tok, 0, &ctx, &kd4, &vd4)
        .unwrap();
    let v = rt.cfg.vocab;
    for (a, b) in o1.logits.f32s()[..v].iter().zip(&o4.logits.f32s()[..v]) {
        assert!((a - b).abs() < 1e-4, "padding changed live row: {a} vs {b}");
    }
}

#[test]
fn bucket_selection_through_runtime() {
    let man = Manifest::load(&artifacts_root()).unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&man, &client, "pico-mh").unwrap();
    assert_eq!(rt.bucket_for(3).unwrap(), 4);
    assert_eq!(rt.bucket_for(32).unwrap(), 32);
    assert!(rt.bucket_for(64).is_err());
}
