//! Integration over the simulator: the qualitative claims of every paper
//! table/figure, checked as assertions (who wins, by what factor, where
//! the crossovers and OOM boundaries fall).

use bifurcated_attn::attention::{
    avg_decode_latency, decode_latency, h100, is_oom, paper_16b_mh, paper_7b_gqa,
    paper_7b_mha, AttnImpl,
};
use bifurcated_attn::bench::Cell;
use bifurcated_attn::simulator::sweep;
use bifurcated_attn::simulator::{TABLE6_COLUMNS, TABLE7_COLUMNS};

#[test]
fn abstract_headline_speedups() {
    // Abstract: ">2.1x speedup at 16 sequences, >6.2x at 32 sequences for
    // context >= 8k on a 7B MH model". Check the simulator reproduces at
    // least those factors (eager SDPA vs bifurcated).
    let m = paper_7b_mha();
    let hw = h100();
    let speedup = |b: usize, ctx: usize| {
        decode_latency(&m, &hw, AttnImpl::SdpaContiguous, false, b, ctx, 16).seconds
            / decode_latency(&m, &hw, AttnImpl::Bifurcated, false, b, ctx, 16).seconds
    };
    assert!(speedup(16, 8192) > 2.1, "b=16: {}", speedup(16, 8192));
    assert!(speedup(32, 8192) > 4.0, "b=32: {}", speedup(32, 8192));
    assert!(speedup(32, 16384) > 6.2, "b=32 @16k: {}", speedup(32, 16384));
}

#[test]
fn table6_shape_matches_paper() {
    let m = paper_7b_mha();
    let hw = h100();
    let t = sweep::paper_latency_table(
        "t6", &m, &hw, &[8192, 16384, 32640], TABLE6_COLUMNS,
        &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048],
    );
    let col = |label: &str| {
        2 + TABLE6_COLUMNS.iter().position(|c| c.label == label).unwrap()
    };
    let ms = |cell: &Cell| match cell {
        Cell::Ms(v) => Some(*v),
        _ => None,
    };
    // paper: at 8k, eager bifurcated stays ~flat from b=1 to b=64 while
    // SDPA Math grows several-fold before hitting OOM
    let rows8k: Vec<_> = t.rows.iter().take(12).collect();
    let bif = col("Bifurcated");
    let sdpa = col("SDPA Math");
    let bif_b1 = ms(&rows8k[0][bif]).unwrap();
    let bif_b64 = ms(&rows8k[6][bif]).unwrap();
    assert!(bif_b64 / bif_b1 < 1.6, "bifurcated growth {}", bif_b64 / bif_b1);
    let sdpa_b1 = ms(&rows8k[0][sdpa]).unwrap();
    // largest batch where the SDPA column still measures
    let (sdpa_last_b, sdpa_last) = rows8k
        .iter()
        .filter_map(|r| match (&r[1], ms(&r[sdpa])) {
            (Cell::Num(b), Some(v)) => Some((*b as usize, v)),
            _ => None,
        })
        .last()
        .unwrap();
    assert!(sdpa_last_b >= 8, "SDPA should survive to at least b=8 at 8k");
    assert!(sdpa_last / sdpa_b1 > 2.0, "sdpa growth {}", sdpa_last / sdpa_b1);
    // SDPA must OOM somewhere at 8k within the ladder; bifurcated
    // survives orders of magnitude deeper (paper: compiled bif OOMs only
    // at b=2048 @8k)
    assert!(rows8k.iter().any(|r| matches!(r[sdpa], Cell::Oom)));
    let first_oom = |c: usize| rows8k.iter().position(|r| matches!(r[c], Cell::Oom));
    let bif_oom = first_oom(bif).unwrap_or(12);
    let sdpa_oom = first_oom(sdpa).unwrap();
    assert!(bif_oom >= sdpa_oom + 5, "bif OOM idx {bif_oom} vs sdpa {sdpa_oom}");
    assert!(rows8k[9].iter().skip(2).take(1).all(|_| true)); // b=512 row exists
    assert!(matches!(rows8k[9][bif], Cell::Ms(_)), "bifurcated must survive b=512 @8k");
    // paper: at b=1 bifurcated (eager) is slightly *slower* than SDPA —
    // the FAQ-4 small-workload overhead
    assert!(bif_b1 > sdpa_b1 * 0.9, "b=1: bif {bif_b1} vs sdpa {sdpa_b1}");
    // compiled columns are much faster than eager at small b
    let cbif = col("Bifurcated+Compile");
    let cbif_b1 = ms(&rows8k[0][cbif]).unwrap();
    assert!(cbif_b1 < 0.6 * bif_b1, "compile speedup at b=1: {cbif_b1} vs {bif_b1}");
}

#[test]
fn table7_gqa_shape() {
    // GQA (g=8): KV IO is 4x smaller, so fused survives deeper but
    // bifurcated still wins at scale and survives to b >= 512 at 8k.
    let m = paper_7b_gqa();
    let hw = h100();
    let t = sweep::paper_latency_table(
        "t7", &m, &hw, &[8192, 16384, 32640], TABLE7_COLUMNS,
        &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512],
    );
    assert_eq!(t.headers.len(), 2 + TABLE7_COLUMNS.len());
    // bifurcated+compile at 8k b=256 must still be "fast" (paper: 24.4 ms)
    let row_256_8k = t.rows.iter().find(|r| {
        matches!(&r[0], Cell::Str(s) if s == "8k") && matches!(r[1], Cell::Num(n) if n == 256.0)
    }).unwrap();
    match &row_256_8k[2] {
        Cell::Ms(v) => assert!(*v < 60.0, "b=256 8k bif+compile: {v}"),
        other => panic!("expected Ms, got {other:?}"),
    }
}

#[test]
fn table8_tp2_shape() {
    // TP=2: capacity doubles (survives 32k b=32 where TP=1 OOMs) and
    // per-token latency drops vs TP=1.
    let m = sweep::table8_model();
    let hw = h100();
    let tp2 = hw.tensor_parallel(2);
    // the replicating SDPA baseline OOMs at 32k b=32 on one GPU; TP=2
    // doubles capacity and pushes the boundary out (paper Table 8 shows
    // SDPA OOM at b=32 even at TP=2; our capacity model puts it within
    // one ladder step of that).
    assert!(is_oom(&m, &hw, AttnImpl::SdpaContiguous, 32, 32640, 64));
    assert!(!is_oom(&m, &tp2, AttnImpl::SdpaContiguous, 16, 32640, 64));
    assert!(is_oom(&m, &tp2, AttnImpl::SdpaContiguous, 64, 32640, 64));
    let l1 = avg_decode_latency(&m, &hw, AttnImpl::SdpaNc, true, 16, 32640, 64);
    let l2 = avg_decode_latency(&m, &tp2, AttnImpl::SdpaNc, true, 16, 32640, 64);
    assert!(l2 < l1);
    // bifurcated under TP stays nearly flat across b (paper Table 8:
    // 55-68 ms from b=8 to 128)
    let b8 = avg_decode_latency(&m, &tp2, AttnImpl::Bifurcated, true, 8, 32640, 64);
    let b128 = avg_decode_latency(&m, &tp2, AttnImpl::Bifurcated, true, 128, 32640, 64);
    assert!(b128 / b8 < 1.5, "{}", b128 / b8);
}

#[test]
fn fig8_batch_size_comparison_codegen() {
    // Paper Sec. 1: CodeGen-16B at 2k context — bifurcation lifts the
    // feasible batch from ~5 to >= 128 within a fixed latency budget.
    let hw = h100();
    let budget = 2.0 * sweep::fig8_latency_axis(&hw, 1, 2048, 128, false);
    let max_n = |bif: bool| {
        let mut best = 0;
        for n in [1, 2, 4, 8, 16, 32, 64, 128, 256] {
            let t = sweep::fig8_latency_axis(&hw, n, 2048, 128, bif);
            if t.is_finite() && t <= budget {
                best = n;
            }
        }
        best
    };
    let without = max_n(false);
    let with = max_n(true);
    assert!(without <= 16, "baseline feasible n: {without}");
    assert!(with >= 128, "bifurcated feasible n: {with}");
}

#[test]
fn fig10_star_coder_mq_also_benefits() {
    // Fig 8c/d & 10: StarCoder (MQ) also gains from bifurcation at high n,
    // though less than MH (its KV is already h-times compressed).
    let m = bifurcated_attn::attention::paper_15b_mq();
    let hw = h100();
    let gain = |n: usize| {
        avg_decode_latency(&m, &hw, AttnImpl::SdpaContiguous, false, n, 2048, 128)
            / avg_decode_latency(&m, &hw, AttnImpl::Bifurcated, false, n, 2048, 128)
    };
    assert!(gain(256) > 1.1, "MQ gain at n=256: {}", gain(256));
    let mh_gain = {
        let mh = paper_16b_mh();
        avg_decode_latency(&mh, &hw, AttnImpl::SdpaContiguous, false, 256, 2048, 128)
            / avg_decode_latency(&mh, &hw, AttnImpl::Bifurcated, false, 256, 2048, 128)
    };
    assert!(mh_gain > gain(256), "MH should gain more than MQ");
}
