//! Engine error paths on the native backend: a mid-wave decode failure
//! and KV lease exhaustion must both roll back cleanly — no leaked
//! sequences, no leaked active contexts, `check_invariants()` green —
//! and the engine must keep serving afterwards.

use std::cell::Cell;

use anyhow::Result;

use bifurcated_attn::coordinator::{
    Engine, EngineConfig, GenerationRequest, ModePolicy, SamplingParams,
};
use bifurcated_attn::runtime::manifest::ModelCfg;
use bifurcated_attn::runtime::models::{DecodeMode, DecodeOut, PrefillOut};
use bifurcated_attn::runtime::{Backend, HostTensor, NativeBackend, NativeContext, TokenizerInfo};

/// Delegates to the real native backend but fails the Nth decode call —
/// the injection point for mid-wave faults.
struct FailingBackend {
    inner: NativeBackend,
    decode_calls: Cell<usize>,
    fail_at: Cell<usize>,
}

impl FailingBackend {
    fn new(model: &str, fail_at: usize) -> FailingBackend {
        FailingBackend {
            inner: NativeBackend::preset(model, 0).unwrap(),
            decode_calls: Cell::new(0),
            fail_at: Cell::new(fail_at),
        }
    }
}

impl Backend for FailingBackend {
    type Ctx = NativeContext;

    fn name(&self) -> &'static str {
        "failing-native"
    }

    fn cfg(&self) -> &ModelCfg {
        self.inner.cfg()
    }

    fn buckets(&self) -> &[usize] {
        self.inner.buckets()
    }

    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        self.inner.prefill(tokens)
    }

    fn upload_context(
        &self,
        kc: &HostTensor,
        vc: &HostTensor,
        m_c_len: usize,
    ) -> Result<NativeContext> {
        self.inner.upload_context(kc, vc, m_c_len)
    }

    fn decode(
        &self,
        mode: DecodeMode,
        bucket: usize,
        tokens: &[i32],
        d_pos: usize,
        ctx: &NativeContext,
        kd: &HostTensor,
        vd: &HostTensor,
    ) -> Result<DecodeOut> {
        let n = self.decode_calls.get() + 1;
        self.decode_calls.set(n);
        if n >= self.fail_at.get() {
            anyhow::bail!("injected decode fault at call {n}");
        }
        self.inner.decode(mode, bucket, tokens, d_pos, ctx, kd, vd)
    }

    fn upload_bytes(&self) -> usize {
        self.inner.upload_bytes()
    }
}

fn req(id: u64, n: usize, max_tokens: usize) -> GenerationRequest {
    GenerationRequest {
        id,
        prompt: "10+2=12;11+3=14;12+4=".into(),
        params: SamplingParams {
            n,
            temperature: 1.0,
            top_p: 1.0,
            max_tokens,
            stop_token: None,
            seed: id,
            mode: None,
            deadline_ms: None,
        },
    }
}

#[test]
fn mid_wave_decode_failure_rolls_back_bifurcated() {
    let mut cfg = EngineConfig::default();
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    let engine = Engine::new(TokenizerInfo::builtin(), FailingBackend::new("pico-mq", 2), cfg);

    let err = engine.generate(&req(1, 2, 4)).unwrap_err();
    assert!(format!("{err:#}").contains("injected decode fault"), "{err:#}");

    engine.kv.borrow().check_invariants().unwrap();
    engine.cache.borrow().check_invariants(&engine.kv.borrow()).unwrap();
    let st = engine.kv.borrow().stats();
    assert_eq!(st.sequences, 0, "leases must be returned on failure");
    assert_eq!(
        st.contexts, st.cached_contexts,
        "no active context may leak; only the cache node persists"
    );

    // the cache node survives the failed request: recovery is warm
    engine.rt.fail_at.set(usize::MAX);
    let ok = engine.generate(&req(2, 2, 4)).unwrap();
    assert_eq!(ok.completions.len(), 2);
    assert!(ok.timing.cache_hit_tokens > 0, "retry should hit the cached prefix");
    assert_eq!(ok.timing.upload_bytes, 0);
}

#[test]
fn mid_wave_decode_failure_rolls_back_fused() {
    let mut cfg = EngineConfig::default();
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Fused);
    let engine = Engine::new(TokenizerInfo::builtin(), FailingBackend::new("pico-mq", 3), cfg);

    engine.generate(&req(1, 4, 4)).unwrap_err();
    engine.kv.borrow().check_invariants().unwrap();
    let st = engine.kv.borrow().stats();
    // fused requests own their (replicated) registration and never cache
    assert_eq!((st.contexts, st.sequences, st.used_blocks), (0, 0, 0));

    engine.rt.fail_at.set(usize::MAX);
    assert_eq!(engine.generate(&req(2, 4, 4)).unwrap().completions.len(), 4);
}

#[test]
fn failure_in_a_later_wave_returns_earlier_leases_too() {
    // n=40 runs as waves of 32 + 8; fail deep enough that wave 0 finished
    let mut cfg = EngineConfig::default();
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    let engine = Engine::new(TokenizerInfo::builtin(), FailingBackend::new("pico-mq", 6), cfg);
    engine.generate(&req(1, 40, 4)).unwrap_err();
    engine.kv.borrow().check_invariants().unwrap();
    let st = engine.kv.borrow().stats();
    assert_eq!(st.sequences, 0);
    assert_eq!(st.contexts, st.cached_contexts);
}

#[test]
fn lease_exhaustion_rolls_back_and_recovers() {
    // Room for the cached context (2 blocks) plus 4 decode slots; n=8
    // needs 8 slots, so the 5th lease exhausts capacity with nothing
    // evictable (the request's own node is pinned).
    let be = NativeBackend::preset("pico-mq", 0).unwrap();
    let bpt = be.cfg().kv_bytes_per_token();
    let mut cfg = EngineConfig::default();
    cfg.block_tokens = 16;
    cfg.kv_capacity_bytes = 6 * 16 * bpt;
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    let engine = Engine::native("pico-mq", 0, cfg).unwrap();

    let err = engine.generate(&req(1, 8, 8)).unwrap_err();
    assert!(format!("{err:#}").contains("KV capacity"), "{err:#}");
    engine.kv.borrow().check_invariants().unwrap();
    engine.cache.borrow().check_invariants(&engine.kv.borrow()).unwrap();
    let st = engine.kv.borrow().stats();
    assert_eq!(st.sequences, 0, "partial leases must be rolled back");
    assert_eq!(st.contexts, st.cached_contexts, "no active context leaked");

    // a smaller batch fits — and is warm, since the prefill was cached
    let ok = engine.generate(&req(2, 4, 8)).unwrap();
    assert_eq!(ok.completions.len(), 4);
    assert!(ok.timing.cache_hit_tokens > 0);
    engine.kv.borrow().check_invariants().unwrap();
}

#[test]
fn injected_lease_exhaustion_mid_wave_recovers_via_eviction() {
    // Chaos-injected allocator exhaustion (no real capacity pressure):
    // the engine must treat it exactly like a full pool — roll the
    // partial lease group back, evict a cold prefix-cache node, and
    // retry to success.
    bifurcated_attn::util::failpoint::clear();
    let mut cfg = EngineConfig::default();
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    let engine = Engine::native("pico-mq", 0, cfg).unwrap();

    // Request A leaves a cold (unpinned) cache node behind.
    engine.generate(&req(1, 2, 4)).unwrap();
    assert_eq!(engine.cache.borrow().len(), 1);

    // Request B, different prefix: its first lease hits the failpoint.
    bifurcated_attn::util::failpoint::set("lease_oom=1@1");
    let mut b = req(2, 2, 4);
    b.prompt = "20+3=23;21+4=25;22+5=".into();
    let ok = engine.generate(&b).unwrap();
    bifurcated_attn::util::failpoint::clear();
    assert_eq!(ok.completions.len(), 2, "retry after eviction must succeed");

    let evictions = engine.cache.borrow().stats().evictions;
    assert_eq!(evictions, 1, "recovery path must evict the cold node");
    assert_eq!(engine.cache.borrow().len(), 1, "only B's node remains cached");
    engine.kv.borrow().check_invariants().unwrap();
    engine.cache.borrow().check_invariants(&engine.kv.borrow()).unwrap();
    let st = engine.kv.borrow().stats();
    assert_eq!(st.sequences, 0, "all leases returned after the wave drained");
    assert_eq!(st.contexts, st.cached_contexts, "no active context leaked");
}
