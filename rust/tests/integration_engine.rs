//! Integration: the full serving engine over real artifacts — scheduler,
//! KV accounting, sampler, waves, reranking, eval harness, HTTP API.
//! Requires a `--features pjrt` build plus `make artifacts`.

#![cfg(feature = "pjrt")]

use bifurcated_attn::coordinator::{
    rerank_top_k, Engine, EngineConfig, GenerationRequest, ModePolicy, SamplingParams,
};
use bifurcated_attn::corpus;
use bifurcated_attn::evalharness::{run_suite, SuiteConfig};
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::{cpu_client, Manifest, ModelRuntime};

fn engine(model: &str, cfg: EngineConfig) -> Engine<ModelRuntime> {
    let man = Manifest::load(&Manifest::default_root()).expect("run `make artifacts`");
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&man, &client, model).unwrap();
    Engine::new(man.tokenizer.clone(), rt, cfg)
}

fn req(prompt: &str, n: usize, seed: u64) -> GenerationRequest {
    GenerationRequest {
        id: seed,
        prompt: prompt.into(),
        params: SamplingParams {
            n,
            temperature: 0.8,
            top_p: 0.95,
            max_tokens: 6,
            stop_token: Some(corpus::SEMI),
            seed,
            mode: None,
            deadline_ms: None,
        },
    }
}

#[test]
fn single_context_batch_sampling_end_to_end() {
    let e = engine("pico-mq", EngineConfig::default());
    let mut r = req("10+2=12;11+3=14;12+4=", 8, 42);
    r.params.temperature = 0.5; // concentrate around the model's argmax
    let res = e.generate(&r).unwrap();
    assert_eq!(res.completions.len(), 8);
    assert_eq!(res.timing.waves, 1);
    assert!(res.timing.decode_steps >= 1);
    // with m_c ~ 22 tokens and n=8 the FAQ-4 switch picks bifurcated
    assert_eq!(res.mode_used, DecodeMode::Bifurcated);
    // the trained model answers 12+4 correctly in most of 8 samples
    let correct = res.completions.iter().filter(|c| c.text.starts_with("16;")).count();
    assert!(correct >= 3, "only {correct}/8 correct: {:?}",
        res.completions.iter().map(|c| c.text.as_str()).collect::<Vec<_>>());
    // reranking keeps a correct one in top-3
    let top = rerank_top_k(&res.completions, 3);
    assert!(top.iter().any(|c| c.text.starts_with("16;")));
}

#[test]
fn greedy_is_deterministic_across_modes() {
    // temperature 0: same completions under forced bifurcated vs fused —
    // the exactness claim observed at the serving API level.
    let mk = |mode| {
        let mut cfg = EngineConfig::default();
        cfg.scheduler.policy = ModePolicy::Force(mode);
        let e = engine("pico-mh", cfg);
        let mut r = req("10+2=12;11+3=14;12+4=", 4, 7);
        r.params.temperature = 0.0;
        e.generate(&r).unwrap()
    };
    let bif = mk(DecodeMode::Bifurcated);
    let fus = mk(DecodeMode::Fused);
    let texts = |r: &bifurcated_attn::coordinator::RequestResult| {
        r.completions.iter().map(|c| c.text.clone()).collect::<Vec<_>>()
    };
    assert_eq!(texts(&bif), texts(&fus));
    assert_eq!(bif.mode_used, DecodeMode::Bifurcated);
    assert_eq!(fus.mode_used, DecodeMode::Fused);
    // greedy all-identical rows
    assert!(bif.completions.windows(2).all(|w| w[0].text == w[1].text));
    // and correct: 12+4=16
    assert!(bif.completions[0].text.starts_with("16;"), "{}", bif.completions[0].text);
}

#[test]
fn waves_cover_n_beyond_max_bucket() {
    let e = engine("pico-mq", EngineConfig::default());
    let res = e.generate(&req("9+9=18;1+1=2;6+6=", 40, 3)).unwrap();
    assert_eq!(res.completions.len(), 40);
    assert_eq!(res.timing.waves, 2, "40 = 32 + 8");
    // every sampler produced at least one token
    assert!(res.completions.iter().all(|c| !c.tokens.is_empty()));
}

#[test]
fn seeds_change_samples_and_are_reproducible() {
    let e = engine("pico-mq", EngineConfig::default());
    // hot distributions need heat to diverge: T=1.5, no nucleus cut
    let hot = |seed| {
        let mut r = req("3+9=", 8, seed);
        r.params.temperature = 1.5;
        r.params.top_p = 1.0;
        r
    };
    let r1 = e.generate(&hot(1)).unwrap();
    let r1b = e.generate(&hot(1)).unwrap();
    let r2 = e.generate(&hot(2)).unwrap();
    let texts = |r: &bifurcated_attn::coordinator::RequestResult| {
        r.completions.iter().map(|c| c.text.clone()).collect::<Vec<_>>()
    };
    assert_eq!(texts(&r1), texts(&r1b), "same seed, same samples");
    assert_ne!(texts(&r1), texts(&r2), "different seed should differ");
}

#[test]
fn kv_accounting_returns_to_zero_and_metrics_accumulate() {
    let e = engine("pico-mq", EngineConfig::default());
    for i in 0..3 {
        e.generate(&req("1+2=", 4, i)).unwrap();
    }
    let stats = e.kv.borrow().stats();
    assert_eq!(stats.contexts, 0);
    assert_eq!(stats.sequences, 0);
    assert_eq!(stats.used_blocks, 0);
    assert_eq!(e.metrics.requests(), 3);
    let report = e.metrics.report();
    assert_eq!(report.f64_of("completions"), 12.0);
    assert!(report.f64_of("upload_bytes") > 0.0);
}

#[test]
fn fused_uploads_strictly_more_context_bytes() {
    // The measurable CPU-side analogue of Eq. 5 vs 6: the fused baseline
    // moves ~bucket x more context KV to the device.
    let run = |mode| {
        let mut cfg = EngineConfig::default();
        cfg.scheduler.policy = ModePolicy::Force(mode);
        let e = engine("pico-mh", cfg);
        let r = e.generate(&req("12+13=25;14+15=29;16+17=", 16, 5)).unwrap();
        r.timing.upload_bytes
    };
    let bif = run(DecodeMode::Bifurcated);
    let fus = run(DecodeMode::Fused);
    assert!(
        fus as f64 > bif as f64 * 1.5,
        "fused {fus} bytes should far exceed bifurcated {bif}"
    );
}

#[test]
fn kv_capacity_exhaustion_is_a_clean_error() {
    let mut cfg = EngineConfig::default();
    cfg.kv_capacity_bytes = 4 << 10; // absurdly small
    let e = engine("pico-mq", cfg);
    let err = e.generate(&req("1+1=", 64, 0)).unwrap_err();
    assert!(format!("{err:#}").contains("KV capacity"), "{err:#}");
    // engine state must be clean afterwards (nothing leaked)
    let stats = e.kv.borrow().stats();
    assert_eq!(stats.used_blocks, 0);
}

#[test]
fn eval_harness_pass_at_n_improves_with_n() {
    let e = engine("pico-mq", EngineConfig::default());
    let res = run_suite(
        &e,
        &SuiteConfig { n_tasks: 12, n_samples: 8, seed: 99, ..Default::default() },
    )
    .unwrap();
    assert_eq!(res.pass_at.len(), 8);
    // monotone non-decreasing in k by construction; strictly better by k=8
    assert!(res.pass_at[7] >= res.pass_at[0]);
    assert!(res.pass_at[0] > 0.2, "pass@1 too low: {}", res.pass_at[0]);
    assert!(res.pass_at[7] > res.pass_at[0] + 0.05,
        "pass@8 {} should beat pass@1 {}", res.pass_at[7], res.pass_at[0]);
    assert!(res.pass_top3 >= res.pass_at[0] - 0.1);
    assert!(res.mean_latency_ms > 0.0);
}

#[test]
fn http_api_serves_generation() {
    use std::io::{Read, Write};
    let client = bifurcated_attn::server::spawn_engine(
        Manifest::default_root(),
        "pico-mq".into(),
        EngineConfig::default(),
    )
    .unwrap();
    let server = bifurcated_attn::server::build_server(client);
    let shutdown = bifurcated_attn::server::Shutdown::new();
    let flag = std::sync::Arc::clone(&shutdown);
    let t = std::thread::spawn(move || {
        server.serve("127.0.0.1:34981", 2, Some(flag)).unwrap();
    });
    std::thread::sleep(std::time::Duration::from_millis(200));

    let body = r#"{"prompt":"2+3=5;4+5=9;6+7=","n":4,"rerank_top_k":3,"seed":1}"#;
    let mut stream = std::net::TcpStream::connect("127.0.0.1:34981").unwrap();
    write!(
        stream,
        "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let json_body = resp.split("\r\n\r\n").nth(1).unwrap();
    let doc = bifurcated_attn::util::json::parse(json_body).unwrap();
    assert_eq!(doc.req("completions").as_arr().unwrap().len(), 4);
    assert!(doc.get("reranked").is_some());
    assert!(doc.req("timing").f64_of("decode_steps") >= 1.0);

    shutdown.trigger();
    t.join().unwrap();
}
