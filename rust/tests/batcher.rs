//! Batcher lifecycle around the parity core: admission-window coalescing
//! end-to-end through the server API layer, solo fallbacks for
//! non-coalescible requests, error replies, and resource hygiene (pins,
//! leases, KV invariants) after waves drain.

use std::cell::RefCell;
use std::rc::Rc;

use bifurcated_attn::coordinator::batcher::{BatchConfig, BatchJob, Batcher, ScriptedSource};
use bifurcated_attn::coordinator::{
    Engine, EngineConfig, GenerationRequest, ModePolicy, RequestResult, SamplingParams,
};
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::NativeBackend;
use bifurcated_attn::server::{parse_generate_body, spawn_native_engine};

fn engine() -> Engine<NativeBackend> {
    Engine::native("pico-mq", 0, EngineConfig::default()).unwrap()
}

fn req(id: u64, prompt: &str, n: usize, mode: Option<ModePolicy>) -> GenerationRequest {
    GenerationRequest {
        id,
        prompt: prompt.into(),
        params: SamplingParams {
            n,
            temperature: 0.8,
            top_p: 0.95,
            max_tokens: 4,
            stop_token: None,
            seed: id,
            mode,
            deadline_ms: None,
        },
    }
}

fn run_one(engine: &Engine<NativeBackend>, r: GenerationRequest) -> anyhow::Result<RequestResult> {
    let out: Rc<RefCell<Option<anyhow::Result<RequestResult>>>> = Rc::new(RefCell::new(None));
    let sink = Rc::clone(&out);
    let mut src: ScriptedSource<NativeBackend> = ScriptedSource::new();
    src.push(
        0,
        BatchJob::Generate(
            r,
            None,
            Box::new(move |res| {
                *sink.borrow_mut() = Some(res);
            }),
        ),
    );
    Batcher::new(engine, BatchConfig { window_us: 0, max_wave_rows: 0 }).run(&mut src);
    Rc::try_unwrap(out).ok().expect("sink still shared").into_inner().expect("no reply")
}

#[test]
fn admission_window_coalesces_concurrent_api_calls() {
    // Two HTTP-layer calls race into a 300 ms admission window: the
    // engine-thread batcher must serve them as ONE shared wave.
    let mut cfg = EngineConfig::default();
    cfg.batching.window_us = 300_000;
    let client = spawn_native_engine("pico-mq".into(), 0, cfg).unwrap();

    let body = r#"{"prompt":"10+2=12;11+3=","n":2,"max_tokens":4,"stop":null,"mode":"bifurcated"}"#;
    let (r1, k1, _) = parse_generate_body(body, 1).unwrap();
    let (r2, k2, _) = parse_generate_body(body, 2).unwrap();
    let c2 = std::sync::Arc::clone(&client);
    let t = std::thread::spawn(move || c2.generate(r2, k2).unwrap());
    let res1 = client.generate(r1, k1).unwrap();
    let res2 = t.join().unwrap();
    assert_eq!(res1.req("completions").as_arr().unwrap().len(), 2);
    assert_eq!(res2.req("completions").as_arr().unwrap().len(), 2);

    let met = client.metrics();
    let batch = met.req("batch");
    assert_eq!(batch.f64_of("waves"), 1.0, "window must coalesce both calls into one wave");
    assert_eq!(batch.f64_of("coalesced_requests"), 2.0);
    assert_eq!(batch.f64_of("peak_rows"), 4.0);
    assert!(batch.f64_of("ctx_sweep_bytes") > 0.0);
    // each response reports the union width it rode in
    assert_eq!(res1.req("timing").f64_of("coalesced_peak_rows"), 4.0);
    assert_eq!(res2.req("timing").f64_of("coalesced_peak_rows"), 4.0);
}

#[test]
fn forced_fused_requests_fall_back_to_the_solo_path() {
    let e = engine();
    let res = run_one(&e, req(1, "1+2=", 4, Some(ModePolicy::Force(DecodeMode::Fused)))).unwrap();
    assert_eq!(res.mode_used, DecodeMode::Fused);
    assert_eq!(res.completions.len(), 4);
    assert_eq!(res.timing.coalesced_peak_rows, 0, "solo path reports no coalescing");
    let counters = e.metrics.batch_counters();
    assert_eq!(counters.batched_requests, 0);
    assert_eq!(counters.waves, 0);
    assert_eq!(e.metrics.requests(), 1, "solo fallback still counts the request");
}

#[test]
fn small_auto_requests_run_solo_and_cold_bifurcated_parks() {
    let e = engine();
    // tiny auto workload: fused solo (below the FAQ-4 threshold)
    let res = run_one(&e, req(1, "1+2=", 1, None)).unwrap();
    assert_eq!(res.mode_used, DecodeMode::Fused);
    assert_eq!(e.metrics.batch_counters().batched_requests, 0);
    // a big auto workload picks bifurcated, populates the cache, and is
    // served as a (single-request) wave
    let res = run_one(&e, req(2, "10+2=12;11+3=14;12+4=", 8, None)).unwrap();
    assert_eq!(res.mode_used, DecodeMode::Bifurcated);
    let counters = e.metrics.batch_counters();
    assert_eq!(counters.batched_requests, 1);
    assert_eq!(counters.coalesced_requests, 0, "alone in the wave");
    assert_eq!(counters.waves, 1);
}

#[test]
fn prepare_errors_reply_cleanly() {
    let e = engine();
    let err = run_one(&e, req(1, "hello world", 2, None)).unwrap_err();
    assert!(format!("{err:#}").contains("not in vocabulary"), "{err:#}");
    // nothing leaked
    let kv = e.kv.borrow().stats();
    assert_eq!((kv.contexts, kv.sequences, kv.used_blocks), (0, 0, 0));
    assert_eq!(e.metrics.batch_counters().batched_requests, 0);
}

#[test]
fn pins_release_after_waves_drain() {
    let e = engine();
    let reqs: Vec<(usize, GenerationRequest)> = (1..=3u64)
        .map(|id| (0usize, req(id, "10+2=12;11+3=14;12+4=", 2, Some(ModePolicy::Force(DecodeMode::Bifurcated)))))
        .collect();
    let out: Rc<RefCell<Vec<RequestResult>>> = Rc::new(RefCell::new(Vec::new()));
    let mut src: ScriptedSource<NativeBackend> = ScriptedSource::new();
    for (at, r) in reqs {
        let sink = Rc::clone(&out);
        src.push(
            at,
            BatchJob::Generate(
                r,
                None,
                Box::new(move |res| {
                    sink.borrow_mut().push(res.unwrap());
                }),
            ),
        );
    }
    Batcher::new(&e, BatchConfig { window_us: 0, max_wave_rows: 0 }).run(&mut src);
    assert_eq!(out.borrow().len(), 3);
    // the node must be unpinned now: LRU eviction can reclaim it
    e.kv.borrow().check_invariants().unwrap();
    e.cache.borrow().check_invariants(&e.kv.borrow()).unwrap();
    assert_eq!(e.cache.borrow().len(), 1);
    let evicted = {
        let mut kv = e.kv.borrow_mut();
        e.cache.borrow_mut().evict_lru(&mut kv)
    };
    assert!(evicted, "node still pinned after its waves drained");
    assert_eq!(e.kv.borrow().stats().used_blocks, 0);
}

#[test]
fn inspect_jobs_are_served_between_steps() {
    // A metrics snapshot queued behind a generate must be answered by the
    // same run without waiting for a separate request cycle.
    let e = engine();
    let seen: Rc<RefCell<Option<f64>>> = Rc::new(RefCell::new(None));
    let mut src: ScriptedSource<NativeBackend> = ScriptedSource::new();
    let done: Rc<RefCell<bool>> = Rc::new(RefCell::new(false));
    let done2 = Rc::clone(&done);
    src.push(
        0,
        BatchJob::Generate(
            req(1, "10+2=12;11+3=14;12+4=", 2, Some(ModePolicy::Force(DecodeMode::Bifurcated))),
            None,
            Box::new(move |res| {
                res.unwrap();
                *done2.borrow_mut() = true;
            }),
        ),
    );
    let sink = Rc::clone(&seen);
    src.push(
        2,
        BatchJob::Inspect(Box::new(move |engine: &Engine<NativeBackend>| {
            *sink.borrow_mut() = Some(engine.metrics_report().req("kv").f64_of("sequences"));
        })),
    );
    Batcher::new(&e, BatchConfig { window_us: 0, max_wave_rows: 0 }).run(&mut src);
    assert!(*done.borrow());
    let mid_sequences = seen.borrow().expect("inspect job never ran");
    assert_eq!(mid_sequences, 2.0, "snapshot taken mid-wave must see the leased sequences");
}
