//! End-to-end observability: the tracing recorder, Chrome/Perfetto trace
//! export, Prometheus text exposition, and the flight recorder, all
//! exercised through the real HTTP server.
//!
//! Tests in this binary share one process-global recorder and flight
//! recorder, and run concurrently — so each test asserts on *presence and
//! shape* (its own spans exist and are well-formed), never on exclusive
//! counts, and fingerprints its own requests by a distinctive sampling
//! shape rather than by request id (each server numbers ids from 1).

use std::time::Duration;

use bifurcated_attn::coordinator::EngineConfig;
use bifurcated_attn::observability::{self, prometheus};
use bifurcated_attn::server::{
    build_server, connect_retry, send_request, spawn_native_engine, ClientResponse, Shutdown,
};
use bifurcated_attn::util::json;

const PROMPT: &str = "10+2=12;11+3=14;12+4=";

struct TestServer {
    addr: std::net::SocketAddr,
    shutdown: std::sync::Arc<Shutdown>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    fn start(cfg: EngineConfig) -> TestServer {
        let client = spawn_native_engine("pico-mq".into(), 0, cfg).unwrap();
        let server = build_server(client);
        let shutdown = Shutdown::new();
        let flag = std::sync::Arc::clone(&shutdown);
        let thread = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", 4, Some(flag)).unwrap();
        });
        let addr = shutdown.wait_addr(Duration::from_secs(10)).expect("server never bound");
        TestServer { addr, shutdown, thread: Some(thread) }
    }

    fn request(&self, method: &str, path: &str, body: &str) -> ClientResponse {
        let mut s = connect_retry(self.addr, Duration::from_secs(5)).unwrap();
        send_request(&mut s, method, path, body).unwrap();
        ClientResponse::read_head(s).unwrap()
    }

    fn post(&self, path: &str, body: &str) -> ClientResponse {
        self.request("POST", path, body)
    }

    fn get(&self, path: &str) -> ClientResponse {
        self.request("GET", path, "")
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.shutdown.trigger();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn gen_body(n: usize, max_tokens: usize, stream: bool) -> String {
    format!(
        r#"{{"prompt":"{PROMPT}","n":{n},"max_tokens":{max_tokens},"stop":null,"mode":"bifurcated","stream":{stream}}}"#
    )
}

/// Names present in a trace document's events.
fn span_names(doc: &json::Json) -> Vec<String> {
    doc.req("traceEvents")
        .as_arr()
        .unwrap()
        .iter()
        .map(|e| e.str_or("name", ""))
        .collect()
}

#[test]
fn streamed_request_trace_covers_the_full_lifecycle() {
    observability::set_level(2);
    let mut cfg = EngineConfig::default();
    cfg.batching.window_us = 2000; // exercise the admission-window span
    let srv = TestServer::start(cfg);

    // Two concurrent same-prefix streaming requests: queue park, window
    // hold, wave launch, per-step spans, stream emits, retire.
    let handles: Vec<_> = (0..2)
        .map(|_| {
            let addr = srv.addr;
            std::thread::spawn(move || {
                let mut s = connect_retry(addr, Duration::from_secs(5)).unwrap();
                send_request(&mut s, "POST", "/generate", &gen_body(2, 4, true)).unwrap();
                let mut resp = ClientResponse::read_head(s).unwrap();
                assert_eq!(resp.status, 200);
                let text = resp.read_body().unwrap();
                assert!(text.contains("\"done\""), "missing done chunk in: {text}");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let mut resp = srv.get("/trace");
    assert_eq!(resp.status, 200);
    let doc = json::parse(&resp.read_body().unwrap()).expect("/trace must return valid JSON");
    assert_eq!(doc.str_of("displayTimeUnit"), "ms");
    let events = doc.req("traceEvents").as_arr().unwrap();
    assert!(!events.is_empty(), "trace must hold events");

    // Chrome trace-event well-formedness: every event names itself, sits
    // on a (pid, tid) track, and is a complete span, instant, or metadata
    // record with the matching required fields.
    for ev in events {
        assert!(!ev.str_of("name").is_empty());
        assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
        match ev.str_of("ph").as_str() {
            "X" => {
                assert!(ev.f64_of("dur") >= 0.0);
                assert!(ev.f64_of("ts") >= 0.0);
            }
            "i" => assert_eq!(ev.str_of("s"), "t"),
            "M" => assert_eq!(ev.str_of("name"), "thread_name"),
            other => panic!("unexpected event phase {other:?}"),
        }
    }

    // Full lifecycle coverage: accept -> parse -> serve -> queue -> window
    // -> prefill -> wave steps -> stream emit -> retire -> stream write,
    // plus level-2 kernel phases.
    let names = span_names(&doc);
    for required in [
        "http.accept",
        "http.parse",
        "req.serve",
        "req.queue",
        "wave.window",
        "wave.launch",
        "engine.cache_lookup",
        "engine.prefill",
        "engine.upload",
        "wave.step",
        "stream.emit",
        "req.retire",
        "http.stream_write",
        "kern.score",
        "kern.recomb",
        "kern.value",
    ] {
        assert!(names.iter().any(|n| n == required), "trace is missing span {required:?}");
    }

    // Each wave.step carries the paper's per-step context sweep volume.
    let step = events
        .iter()
        .find(|e| e.str_or("name", "") == "wave.step")
        .expect("wave.step span present");
    let args = step.req("args");
    assert!(args.f64_of("rows") >= 1.0);
    assert!(args.f64_of("sweep_bytes") > 0.0, "sweep_bytes must be recorded per step");

    // ?last=N bounds the snapshot.
    let mut resp = srv.get("/trace?last=5");
    let doc = json::parse(&resp.read_body().unwrap()).unwrap();
    let n_spans = doc
        .req("traceEvents")
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.str_or("ph", "") != "M")
        .count();
    assert!(n_spans <= 5, "?last=5 returned {n_spans} records");
}

#[test]
fn metrics_and_trace_answer_mid_stream() {
    // Level 2, same as the lifecycle test: these tests run concurrently
    // against one process-global recorder, so no test may LOWER the level.
    observability::set_level(2);
    // threads: 2 — the serial executor has no worker pool to report.
    let srv = TestServer::start(EngineConfig { threads: 2, ..EngineConfig::default() });

    // Open a long streaming request, then hit the introspection routes
    // from separate connections while the wave is still decoding.
    let mut stream_resp = srv.post("/generate", &gen_body(4, 48, true));
    assert_eq!(stream_resp.status, 200);
    assert!(stream_resp.next_chunk().unwrap().is_some(), "first token chunk");

    let mut m = srv.get("/metrics");
    assert_eq!(m.status, 200);
    let met = json::parse(&m.read_body().unwrap()).unwrap();
    assert!(met.get("kv").is_some() && met.get("prefix_cache").is_some());
    // The native backend surfaces its worker-pool profile.
    let pool = met.get("pool").expect("native backend must report pool stats");
    assert!(pool.f64_of("threads") >= 1.0);
    assert!(pool.get("workers").and_then(|w| w.as_arr()).is_some());

    let mut t = srv.get("/trace?last=100");
    assert_eq!(t.status, 200);
    assert!(json::parse(&t.read_body().unwrap()).is_ok(), "mid-wave /trace must parse");

    // Drain the stream so the server retires cleanly before shutdown.
    while stream_resp.next_chunk().unwrap().is_some() {}
}

#[test]
fn prometheus_exposition_round_trips_the_validator() {
    let srv = TestServer::start(EngineConfig::default());
    let mut resp = srv.post("/generate", &gen_body(2, 3, false));
    assert_eq!(resp.status, 200);
    let _ = resp.read_body().unwrap();

    let mut resp = srv.get("/metrics?format=prometheus");
    assert_eq!(resp.status, 200);
    assert_eq!(
        resp.headers.get("content-type").map(String::as_str),
        Some("text/plain; version=0.0.4"),
        "prometheus exposition must declare its version"
    );
    let text = resp.read_body().unwrap();
    let samples = prometheus::validate(&text)
        .unwrap_or_else(|e| panic!("invalid prometheus exposition: {e}\n---\n{text}"));
    assert!(samples > 10, "expected a real metric family set, got {samples} samples");
    assert!(text.contains("bifurcated_"), "metrics must carry the bifurcated_ prefix");

    // The default format stays JSON.
    let mut resp = srv.get("/metrics");
    assert_eq!(resp.status, 200);
    assert!(json::parse(&resp.read_body().unwrap()).is_ok());
}

#[test]
fn flight_recorder_reports_finished_requests() {
    let srv = TestServer::start(EngineConfig::default());
    // Fingerprint this test's request by its sampling shape (3 rows x 7
    // tokens): request ids restart at 1 per server, so they collide across
    // the concurrently-running tests in this binary.
    let mut resp = srv.post("/generate", &gen_body(3, 7, false));
    assert_eq!(resp.status, 200);
    let served = json::parse(&resp.read_body().unwrap()).unwrap();
    assert!(served.get("id").is_some(), "responses must echo the request id");

    let mut resp = srv.get("/requests/recent");
    assert_eq!(resp.status, 200);
    let doc = json::parse(&resp.read_body().unwrap()).unwrap();
    let reqs = doc.req("requests").as_arr().unwrap();
    assert_eq!(doc.f64_of("count"), reqs.len() as f64);
    let mine = reqs
        .iter()
        .find(|r| r.str_or("outcome", "") == "ok" && r.f64_of("generated_tokens") == 21.0)
        .expect("finished request must appear in /requests/recent");
    assert_eq!(mine.str_of("mode"), "bifurcated");
    assert!(mine.f64_of("decode_steps") >= 7.0);
    assert!(mine.f64_of("prefill_ms") > 0.0);
    assert!(mine.get("queue_ms").is_some() && mine.get("window_ms").is_some());

    // ?last=1 truncates to the newest entry.
    let mut resp = srv.get("/requests/recent?last=1");
    let doc = json::parse(&resp.read_body().unwrap()).unwrap();
    assert_eq!(doc.req("requests").as_arr().unwrap().len(), 1);
}
