//! Bifurcated-vs-fused exactness on the native backend — the paper's §3
//! claim (Eq. 3–4 produce the same numerics as the unsplit attention) as a
//! property-style test suite.
//!
//! The two decode modes are genuinely different code paths (shared-context
//! two-partition softmax recombination vs per-row replicated context with
//! one concatenated softmax), so agreement here is evidence, not a
//! tautology. Runs the full grid of (batch ∈ {1, 4, 16}, context length ∈
//! {8, 64, 256}, g ∈ {1, h}) plus engine-level and padding checks.
//!
//! Since the kernel rewrite, every grid point additionally holds both
//! optimized modes to ≤1e-5 of the scalar reference oracle
//! (`NativeBackend::{prefill,decode}_reference`) — the blocked/threaded
//! GEMM paths must not drift from the original per-head sweeps.

use bifurcated_attn::coordinator::{
    Engine, EngineConfig, GenerationRequest, ModePolicy, SamplingParams,
};
use bifurcated_attn::corpus;
use bifurcated_attn::runtime::manifest::ModelCfg;
use bifurcated_attn::runtime::{Backend, ContextView, DecodeMode, NativeBackend};
use bifurcated_attn::util::prng::Pcg;

const TOL: f32 = 1e-5;
const DECODE_STEPS: usize = 4;

/// A small-but-real model config sized for one (g, m_c_max) grid point.
fn grid_cfg(g: usize, h: usize, m_c_max: usize) -> ModelCfg {
    let d = 32usize;
    let m_d_max = DECODE_STEPS + 2;
    ModelCfg {
        name: format!("grid-g{g}-mc{m_c_max}"),
        d,
        h,
        g,
        k: d / h,
        p: h / g,
        l: 2,
        vocab: 16,
        ffn_mult: 2,
        m_c_max,
        m_d_max,
        m_max: m_c_max + m_d_max,
        seq_len: 16,
        param_count: 0,
        attention_kind: String::new(),
    }
}

fn random_prompt(rng: &mut Pcg, len: usize) -> Vec<i32> {
    let mut toks = vec![corpus::BOS];
    toks.extend(corpus::token_stream(rng, len - 1));
    toks
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Drive both modes step-by-step over one shared prefill and assert the
/// logits agree within TOL at every step.
fn assert_parity(g: usize, h: usize, m_c_len: usize, b: usize, seed: u64) {
    let be = NativeBackend::new(grid_cfg(g, h, m_c_len), seed).unwrap();
    let cfg = be.cfg().clone();
    let mut rng = Pcg::new(seed ^ 0x9A11);
    let prompt = random_prompt(&mut rng, m_c_len);

    let pre = be.prefill(&prompt).unwrap();
    assert_eq!(pre.logits.len(), cfg.vocab);
    assert!(pre.logits.iter().all(|v| v.is_finite()));

    // optimized prefill vs the scalar oracle
    let pre_ref = be.prefill_reference(&prompt).unwrap();
    assert!(
        max_abs_diff(&pre.logits, &pre_ref.logits) <= TOL,
        "g={g} m_c={m_c_len}: prefill drifts from the scalar oracle"
    );
    assert!(max_abs_diff(pre.kc.f32s(), pre_ref.kc.f32s()) <= TOL);
    assert!(max_abs_diff(pre.vc.f32s(), pre_ref.vc.f32s()) <= TOL);

    // bifurcated: one shared context copy; fused: b replicas
    let ctx_bif = be.upload_context(&pre.kc, &pre.vc, m_c_len).unwrap();
    let kc_rep = pre.kc.broadcast_at(1, b);
    let vc_rep = pre.vc.broadcast_at(1, b);
    let ctx_fus = be.upload_context(&kc_rep, &vc_rep, m_c_len).unwrap();
    assert_eq!(ctx_fus.bytes(), b * ctx_bif.bytes(), "Eq. 5 vs Eq. 6 byte ratio");

    let (mut kd_b, mut vd_b) = be.zero_decode_cache(b);
    let (mut kd_f, mut vd_f) = be.zero_decode_cache(b);
    let mut toks: Vec<i32> = (0..b).map(|_| rng.below(cfg.vocab) as i32).collect();
    for step in 0..DECODE_STEPS {
        let ob = be
            .decode(DecodeMode::Bifurcated, b, &toks, step, &ctx_bif, &kd_b, &vd_b)
            .unwrap();
        let of = be
            .decode(DecodeMode::Fused, b, &toks, step, &ctx_fus, &kd_f, &vd_f)
            .unwrap();
        assert_eq!(ob.logits.shape, vec![b, cfg.vocab]);
        assert_eq!(of.logits.shape, vec![b, cfg.vocab]);
        let diff = max_abs_diff(ob.logits.f32s(), of.logits.f32s());
        assert!(
            diff <= TOL,
            "g={g} m_c={m_c_len} b={b} step {step}: logits differ by {diff}"
        );
        // cache updates must agree too (they feed every later step)
        assert!(max_abs_diff(ob.kd.f32s(), of.kd.f32s()) <= TOL);
        assert!(max_abs_diff(ob.vd.f32s(), of.vd.f32s()) <= TOL);
        assert!(ob.logits.f32s().iter().all(|v| v.is_finite()));
        // both optimized modes vs the scalar oracle, on the same inputs
        let rb = be
            .decode_reference(DecodeMode::Bifurcated, b, &toks, step, &ctx_bif, &kd_b, &vd_b)
            .unwrap();
        let rf = be
            .decode_reference(DecodeMode::Fused, b, &toks, step, &ctx_fus, &kd_f, &vd_f)
            .unwrap();
        let db = max_abs_diff(ob.logits.f32s(), rb.logits.f32s());
        let df = max_abs_diff(of.logits.f32s(), rf.logits.f32s());
        assert!(db <= TOL, "g={g} m_c={m_c_len} b={b} step {step}: bifurcated vs oracle {db}");
        assert!(df <= TOL, "g={g} m_c={m_c_len} b={b} step {step}: fused vs oracle {df}");
        assert!(max_abs_diff(ob.kd.f32s(), rb.kd.f32s()) <= TOL);
        assert!(max_abs_diff(ob.vd.f32s(), rb.vd.f32s()) <= TOL);
        assert!(max_abs_diff(of.kd.f32s(), rf.kd.f32s()) <= TOL);
        assert!(max_abs_diff(of.vd.f32s(), rf.vd.f32s()) <= TOL);
        // greedy-feed each row's argmax so later steps have diverged,
        // non-trivial decode caches
        toks = ob.logits.f32s()[..b * cfg.vocab]
            .chunks_exact(cfg.vocab)
            .map(|row| {
                bifurcated_attn::util::prng::argmax(row).0 as i32
            })
            .collect();
        kd_b = ob.kd;
        vd_b = ob.vd;
        kd_f = of.kd;
        vd_f = of.vd;
    }
}

#[test]
fn parity_grid_multi_query() {
    // g = 1: the multi-query extreme, where context sharing saves the most
    for (i, &mc) in [8usize, 64, 256].iter().enumerate() {
        for (j, &b) in [1usize, 4, 16].iter().enumerate() {
            assert_parity(1, 4, mc, b, 100 + (i * 3 + j) as u64);
        }
    }
}

#[test]
fn parity_grid_multi_head() {
    // g = h: full multi-head, one KV group per query head
    for (i, &mc) in [8usize, 64, 256].iter().enumerate() {
        for (j, &b) in [1usize, 4, 16].iter().enumerate() {
            assert_parity(4, 4, mc, b, 200 + (i * 3 + j) as u64);
        }
    }
}

#[test]
fn parity_multi_group_middle() {
    // 1 < g < h (the generalized case) at one representative shape
    assert_parity(2, 4, 64, 4, 300);
}

#[test]
fn padded_rows_are_inert() {
    // A live batch of 1 padded up to bucket 4 must produce the same row-0
    // logits as bucket 1, in both modes.
    let be = NativeBackend::new(grid_cfg(2, 4, 32), 7).unwrap();
    let cfg = be.cfg().clone();
    let mut rng = Pcg::new(7);
    let prompt = random_prompt(&mut rng, 20);
    let pre = be.prefill(&prompt).unwrap();
    let ctx = be.upload_context(&pre.kc, &pre.vc, prompt.len()).unwrap();
    let tok = [3i32];

    let (kd1, vd1) = be.zero_decode_cache(1);
    let o1 = be.decode(DecodeMode::Bifurcated, 1, &tok, 0, &ctx, &kd1, &vd1).unwrap();
    let (kd4, vd4) = be.zero_decode_cache(4);
    let o4 = be.decode(DecodeMode::Bifurcated, 4, &tok, 0, &ctx, &kd4, &vd4).unwrap();
    let v = cfg.vocab;
    assert!(max_abs_diff(&o1.logits.f32s()[..v], &o4.logits.f32s()[..v]) <= 1e-6);

    let ctx1 = be
        .upload_context(&pre.kc.broadcast_at(1, 1), &pre.vc.broadcast_at(1, 1), prompt.len())
        .unwrap();
    let ctx4 = be
        .upload_context(&pre.kc.broadcast_at(1, 4), &pre.vc.broadcast_at(1, 4), prompt.len())
        .unwrap();
    let f1 = be.decode(DecodeMode::Fused, 1, &tok, 0, &ctx1, &kd1, &vd1).unwrap();
    let f4 = be.decode(DecodeMode::Fused, 4, &tok, 0, &ctx4, &kd4, &vd4).unwrap();
    assert!(max_abs_diff(&f1.logits.f32s()[..v], &f4.logits.f32s()[..v]) <= 1e-6);
}

#[test]
fn identical_sampler_rows_get_identical_logits() {
    // All rows share the context and feed the same token: every logits row
    // must match row 0 (the single-context symmetry the engine relies on).
    let be = NativeBackend::new(grid_cfg(1, 4, 48), 9).unwrap();
    let cfg = be.cfg().clone();
    let mut rng = Pcg::new(9);
    let prompt = random_prompt(&mut rng, 30);
    let pre = be.prefill(&prompt).unwrap();
    let ctx = be.upload_context(&pre.kc, &pre.vc, prompt.len()).unwrap();
    let b = 8;
    let (kd, vd) = be.zero_decode_cache(b);
    let out = be.decode(DecodeMode::Bifurcated, b, &vec![5i32; b], 0, &ctx, &kd, &vd).unwrap();
    let rows: Vec<&[f32]> = out.logits.f32s().chunks_exact(cfg.vocab).collect();
    for (i, row) in rows.iter().enumerate().skip(1) {
        assert!(max_abs_diff(rows[0], row) <= 1e-6, "row {i} diverged");
    }
}

#[test]
fn engine_greedy_is_deterministic_across_modes() {
    // Temperature 0 through the full engine (waves, KV accounting,
    // sampling): forced-bifurcated and forced-fused must emit identical
    // completions — the exactness claim at the serving-API level.
    let run = |mode| {
        let mut cfg = EngineConfig::default();
        cfg.scheduler.policy = ModePolicy::Force(mode);
        let engine = Engine::native("pico-mg", 0, cfg).unwrap();
        let req = GenerationRequest {
            id: 7,
            prompt: "10+2=12;11+3=14;12+4=".into(),
            params: SamplingParams {
                n: 4,
                temperature: 0.0,
                top_p: 0.95,
                max_tokens: 6,
                stop_token: Some(corpus::SEMI),
                seed: 7,
                mode: None,
                deadline_ms: None,
            },
        };
        let res = engine.generate(&req).unwrap();
        // engine state must drain completely: no sequences, no active
        // contexts. Bifurcated runs legitimately retain one *cached*
        // context (the prefix-cache node this request populated).
        let stats = engine.kv.borrow().stats();
        assert_eq!(stats.sequences, 0);
        assert_eq!(stats.contexts, stats.cached_contexts);
        assert!(stats.cached_contexts <= 1);
        engine.kv.borrow().check_invariants().unwrap();
        res
    };
    let bif = run(DecodeMode::Bifurcated);
    let fus = run(DecodeMode::Fused);
    let texts = |r: &bifurcated_attn::coordinator::RequestResult| {
        r.completions.iter().map(|c| c.text.clone()).collect::<Vec<_>>()
    };
    assert_eq!(texts(&bif), texts(&fus));
    assert_eq!(bif.mode_used, DecodeMode::Bifurcated);
    assert_eq!(fus.mode_used, DecodeMode::Fused);
    // greedy rows from one shared context are identical
    assert!(bif.completions.windows(2).all(|w| w[0].text == w[1].text));
    // fused replicates the context per row: strictly more upload traffic
    assert!(
        fus.timing.upload_bytes > bif.timing.upload_bytes,
        "fused {} should exceed bifurcated {}",
        fus.timing.upload_bytes,
        bif.timing.upload_bytes
    );
}

#[test]
fn engine_waves_and_seeds_on_native() {
    // n beyond the largest bucket splits into waves; seeds reproduce.
    let engine = Engine::native("pico-mq", 1, EngineConfig::default()).unwrap();
    let req = |seed| GenerationRequest {
        id: seed,
        prompt: "9+9=18;1+1=2;6+6=".into(),
        params: SamplingParams {
            n: 40,
            temperature: 1.2,
            top_p: 1.0,
            max_tokens: 4,
            stop_token: Some(corpus::SEMI),
            seed,
            mode: None,
            deadline_ms: None,
        },
    };
    let r1 = engine.generate(&req(1)).unwrap();
    assert_eq!(r1.completions.len(), 40);
    assert_eq!(r1.timing.waves, 2, "40 = 32 + 8");
    assert!(r1.completions.iter().all(|c| !c.tokens.is_empty()));
    let r1b = engine.generate(&req(1)).unwrap();
    let r2 = engine.generate(&req(2)).unwrap();
    let texts = |r: &bifurcated_attn::coordinator::RequestResult| {
        r.completions.iter().map(|c| c.text.clone()).collect::<Vec<_>>()
    };
    assert_eq!(texts(&r1), texts(&r1b), "same seed, same samples");
    assert_ne!(texts(&r1), texts(&r2), "different seed should differ");
}

#[test]
fn eval_harness_runs_on_native() {
    use bifurcated_attn::evalharness::{run_suite, SuiteConfig};
    let engine = Engine::native("pico-mq", 2, EngineConfig::default()).unwrap();
    let res = run_suite(
        &engine,
        &SuiteConfig { n_tasks: 4, n_samples: 4, max_tokens: 4, seed: 11, ..Default::default() },
    )
    .unwrap();
    assert_eq!(res.pass_at.len(), 4);
    // untrained weights: no accuracy claim, but the estimator must be
    // well-formed and monotone in k
    for w in res.pass_at.windows(2) {
        assert!(w[1] + 1e-12 >= w[0]);
    }
    assert!(res.mean_latency_ms > 0.0);
}
