//! Cross-request prefix cache, end-to-end on the native backend: warm
//! requests must reproduce cold completions exactly while skipping
//! prefill and context upload; partial hits must extend incrementally;
//! eviction must respect pins and the KV accounting.

use bifurcated_attn::coordinator::{
    Engine, EngineConfig, GenerationRequest, ModePolicy, SamplingParams,
};
use bifurcated_attn::corpus;
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::NativeBackend;

fn req(id: u64, prompt: &str, n: usize, seed: u64) -> GenerationRequest {
    GenerationRequest {
        id,
        prompt: prompt.into(),
        params: SamplingParams {
            n,
            temperature: 0.8,
            top_p: 0.95,
            max_tokens: 6,
            stop_token: Some(corpus::SEMI),
            seed,
            mode: None,
            deadline_ms: None,
        },
    }
}

fn texts(r: &bifurcated_attn::coordinator::RequestResult) -> Vec<String> {
    r.completions.iter().map(|c| c.text.clone()).collect()
}

#[test]
fn warm_hit_reproduces_cold_with_zero_upload() {
    let prompt = "10+2=12;11+3=14;12+4=";
    let engine = Engine::native("pico-mq", 0, EngineConfig::default()).unwrap();
    let prompt_len = engine.tokenize_prompt(prompt).unwrap().len();

    let cold = engine.generate(&req(7, prompt, 8, 5)).unwrap();
    assert_eq!(cold.mode_used, DecodeMode::Bifurcated);
    assert_eq!(cold.timing.cache_hit_tokens, 0);
    assert!(cold.timing.upload_bytes > 0, "cold request uploads the context");

    // identical request again: full hit — no prefill, no context upload
    let warm = engine.generate(&req(7, prompt, 8, 5)).unwrap();
    assert_eq!(texts(&warm), texts(&cold), "warm completions must match cold exactly");
    assert_eq!(warm.timing.cache_hit_tokens, prompt_len);
    assert_eq!(warm.timing.upload_bytes, 0, "warm bifurcated hit skips the upload");
    assert_eq!(warm.mode_used, DecodeMode::Bifurcated);

    // a fresh engine (cold cache) also produces the same completions
    let fresh = Engine::native("pico-mq", 0, EngineConfig::default()).unwrap();
    let cold2 = fresh.generate(&req(7, prompt, 8, 5)).unwrap();
    assert_eq!(texts(&cold2), texts(&warm));

    let stats = engine.cache.borrow().stats();
    assert_eq!(stats.full_hits, 1);
    assert_eq!(stats.misses, 1);
    assert_eq!(stats.entries, 1);
    engine.cache.borrow().check_invariants(&engine.kv.borrow()).unwrap();
}

#[test]
fn partial_hit_prefills_only_the_suffix() {
    let short = "10+2=12;11+3=";
    let long = "10+2=12;11+3=14;12+4=";
    let engine = Engine::native("pico-mg", 1, EngineConfig::default()).unwrap();
    let short_len = engine.tokenize_prompt(short).unwrap().len();
    let long_len = engine.tokenize_prompt(long).unwrap().len();

    engine.generate(&req(1, short, 8, 3)).unwrap();
    let ext = engine.generate(&req(2, long, 8, 9)).unwrap();
    assert_eq!(
        ext.timing.cache_hit_tokens, short_len,
        "the cached short prompt should cover the prefix"
    );
    assert!(ext.timing.cache_hit_tokens < long_len);

    // incremental prefill is exact: a cold engine agrees completion-for-
    // completion with the extended warm path
    let fresh = Engine::native("pico-mg", 1, EngineConfig::default()).unwrap();
    let cold = fresh.generate(&req(2, long, 8, 9)).unwrap();
    assert_eq!(texts(&ext), texts(&cold));

    // the extension became its own node: re-serving `long` is a full hit
    let warm = engine.generate(&req(3, long, 8, 11)).unwrap();
    assert_eq!(warm.timing.cache_hit_tokens, long_len);
    assert_eq!(warm.timing.upload_bytes, 0);
    assert_eq!(engine.cache.borrow().stats().entries, 2);
    engine.cache.borrow().check_invariants(&engine.kv.borrow()).unwrap();
}

#[test]
fn warm_full_hit_tips_auto_mode_to_bifurcated() {
    // n=1 on a short prompt is below the FAQ-4 threshold: cold runs
    // fused. Warm, the shared context is already resident, so auto picks
    // bifurcated and uploads nothing. But fused requests don't populate
    // the cache, so prime it with a bifurcated request first.
    let engine = Engine::native("pico-mq", 2, EngineConfig::default()).unwrap();
    let prompt = "7+8=";
    let greedy = |id: u64, n: usize, mode: Option<ModePolicy>| GenerationRequest {
        id,
        prompt: prompt.into(),
        params: SamplingParams {
            n,
            temperature: 0.0,
            top_p: 0.95,
            max_tokens: 4,
            stop_token: Some(corpus::SEMI),
            seed: 1,
            mode,
            deadline_ms: None,
        },
    };
    let cold = engine
        .generate(&greedy(1, 1, Some(ModePolicy::Force(DecodeMode::Bifurcated))))
        .unwrap();
    let warm = engine.generate(&greedy(2, 1, None)).unwrap();
    assert_eq!(warm.mode_used, DecodeMode::Bifurcated, "full hit flips auto to bifurcated");
    assert_eq!(warm.timing.upload_bytes, 0);
    assert_eq!(texts(&warm), texts(&cold));
    // cold auto at this workload would have been fused
    let fresh = Engine::native("pico-mq", 2, EngineConfig::default()).unwrap();
    assert_eq!(fresh.generate(&greedy(3, 1, None)).unwrap().mode_used, DecodeMode::Fused);
}

#[test]
fn disabled_cache_preserves_the_old_lifecycle() {
    let mut cfg = EngineConfig::default();
    cfg.prefix_cache_entries = 0;
    let engine = Engine::native("pico-mq", 0, cfg).unwrap();
    let a = engine.generate(&req(1, "10+2=12;11+3=14;12+4=", 8, 5)).unwrap();
    let b = engine.generate(&req(2, "10+2=12;11+3=14;12+4=", 8, 5)).unwrap();
    assert_eq!(a.timing.cache_hit_tokens, 0);
    assert_eq!(b.timing.cache_hit_tokens, 0);
    assert!(b.timing.upload_bytes > 0, "no cache: every request re-uploads");
    let stats = engine.kv.borrow().stats();
    assert_eq!((stats.contexts, stats.sequences, stats.used_blocks), (0, 0, 0));
}

#[test]
fn entry_budget_evicts_lru_nodes() {
    let mut cfg = EngineConfig::default();
    cfg.prefix_cache_entries = 2;
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    let engine = Engine::native("pico-mq", 0, cfg).unwrap();
    engine.generate(&req(1, "1+1=", 2, 1)).unwrap();
    engine.generate(&req(2, "2+2=", 2, 2)).unwrap();
    // touch the first so the second becomes LRU
    engine.generate(&req(3, "1+1=", 2, 3)).unwrap();
    engine.generate(&req(4, "3+3=", 2, 4)).unwrap();
    {
        let cache = engine.cache.borrow();
        assert_eq!(cache.stats().entries, 2);
        assert_eq!(cache.stats().evictions, 1);
        cache.check_invariants(&engine.kv.borrow()).unwrap();
    }
    // "2+2=" was evicted; "1+1=" survived
    assert_eq!(engine.generate(&req(5, "1+1=", 2, 5)).unwrap().timing.cache_hit_tokens, 5);
    assert_eq!(engine.generate(&req(6, "2+2=", 2, 6)).unwrap().timing.cache_hit_tokens, 0);
}

#[test]
fn byte_budget_evicts_by_resident_bytes() {
    // Entry budget of 8 but bytes for only 2 resident K_c/V_c pairs: the
    // byte budget must be the binding constraint, LRU order preserved.
    let be = NativeBackend::preset("pico-mq", 0).unwrap();
    let c = &be.cfg;
    let entry_bytes = 2 * c.l * c.g * c.m_c_max * c.k * 4;
    let mut cfg = EngineConfig::default();
    cfg.prefix_cache_entries = 8;
    cfg.prefix_cache_bytes = 2 * entry_bytes;
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    let engine = Engine::native("pico-mq", 0, cfg).unwrap();
    engine.generate(&req(1, "1+1=", 2, 1)).unwrap();
    engine.generate(&req(2, "2+2=", 2, 2)).unwrap();
    // touch the first so the second becomes LRU, then insert a third
    engine.generate(&req(3, "1+1=", 2, 3)).unwrap();
    engine.generate(&req(4, "3+3=", 2, 4)).unwrap();
    {
        let cache = engine.cache.borrow();
        let stats = cache.stats();
        assert_eq!(stats.entries, 2, "byte budget holds 2 entries");
        assert_eq!(stats.resident_bytes, 2 * entry_bytes);
        assert_eq!(stats.evictions, 1);
        cache.check_invariants(&engine.kv.borrow()).unwrap();
    }
    // "2+2=" was the byte-budget victim; "1+1=" survived
    assert_eq!(engine.generate(&req(5, "1+1=", 2, 5)).unwrap().timing.cache_hit_tokens, 5);
    assert_eq!(engine.generate(&req(6, "2+2=", 2, 6)).unwrap().timing.cache_hit_tokens, 0);
    // the /metrics payload carries the resident-bytes gauge
    let m = engine.metrics_report();
    assert_eq!(m.req("prefix_cache").f64_of("resident_bytes"), (2 * entry_bytes) as f64);
    assert_eq!(m.req("prefix_cache").f64_of("max_bytes"), (2 * entry_bytes) as f64);
}

#[test]
fn kv_pressure_evicts_cached_nodes_mid_request() {
    // Capacity of exactly 2 blocks: a request needs 1 block of context +
    // 1 block of decode slot, so serving a *new* prompt while an old
    // cached node is resident only works if lease-time eviction kicks in.
    let be = NativeBackend::preset("pico-mq", 0).unwrap();
    let bpt = be.cfg.kv_bytes_per_token();
    let mut cfg = EngineConfig::default();
    cfg.kv_capacity_bytes = 2 * 16 * bpt;
    cfg.block_tokens = 16;
    cfg.scheduler.policy = ModePolicy::Force(DecodeMode::Bifurcated);
    let engine = Engine::native("pico-mq", 0, cfg).unwrap();
    let go = |id: u64, prompt: &str| {
        let mut r = req(id, prompt, 1, id);
        r.params.max_tokens = 2;
        engine.generate(&r).unwrap()
    };
    go(1, "1+2=");
    assert_eq!(engine.kv.borrow().stats().cached_contexts, 1);
    go(2, "3+4="); // forces eviction of the first node to lease its slot
    {
        let cache = engine.cache.borrow();
        assert_eq!(cache.stats().entries, 1);
        assert_eq!(cache.stats().evictions, 1);
        cache.check_invariants(&engine.kv.borrow()).unwrap();
    }
    engine.kv.borrow().check_invariants().unwrap();
    // the first prompt is cold again, the second warm
    assert_eq!(go(3, "3+4=").timing.cache_hit_tokens, 5);
    engine.kv.borrow().check_invariants().unwrap();
}
