//! Streaming delivery + cancel-on-disconnect, pinned end to end:
//!
//! * **Bitwise parity** — the `(row, token)` events a streamed request
//!   emits concatenate to exactly the per-completion token lists the same
//!   request (same id, so same `wave_seed`) returns buffered, on the solo
//!   path, through the batcher, and over real HTTP chunked transfer.
//! * **Cancel semantics** — flipping the disconnect flag retires the
//!   request at the next step boundary: wave row compacted out, KV leases
//!   and prefix-cache pins released, survivors bit-for-bit undisturbed,
//!   and the `/metrics` cancel counters account for the freed rows.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::mpsc::Receiver;
use std::time::Duration;

use bifurcated_attn::coordinator::batcher::{BatchConfig, BatchJob, Batcher, ScriptedSource};
use bifurcated_attn::coordinator::{
    Cancelled, Engine, EngineConfig, GenerationRequest, ModePolicy, RequestResult, SamplingParams,
    StreamEvent, StreamHandle,
};
use bifurcated_attn::corpus;
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::{NativeBackend, TokenizerInfo};
use bifurcated_attn::server::{
    build_server, connect_retry, send_request, send_request_with, spawn_native_engine,
    ClientResponse, Shutdown,
};
use bifurcated_attn::util::json;

const PROMPT: &str = "10+2=12;11+3=14;12+4=";

fn engine() -> Engine<NativeBackend> {
    Engine::native("pico-mq", 0, EngineConfig::default()).unwrap()
}

fn req(id: u64, n: usize, max_tokens: usize, stop: Option<i32>) -> GenerationRequest {
    GenerationRequest {
        id,
        prompt: PROMPT.into(),
        params: SamplingParams {
            n,
            temperature: 0.8,
            top_p: 0.95,
            max_tokens,
            stop_token: stop,
            seed: id,
            mode: Some(ModePolicy::Force(DecodeMode::Bifurcated)),
            deadline_ms: None,
        },
    }
}

/// Drain a closed event channel into per-completion token lists. Rows are
/// request-global sampler indices, so this is exactly the reconstruction a
/// streaming client performs.
fn rows_from_events(rx: Receiver<StreamEvent>, n_rows: usize) -> Vec<Vec<i32>> {
    let mut rows = vec![Vec::new(); n_rows];
    for ev in rx.iter() {
        assert!(ev.row < n_rows, "row {} out of range {n_rows}", ev.row);
        rows[ev.row].push(ev.token);
    }
    rows
}

fn assert_rows_match(rows: &[Vec<i32>], oracle: &RequestResult, what: &str) {
    assert_eq!(rows.len(), oracle.completions.len(), "{what}: row count");
    for (i, c) in oracle.completions.iter().enumerate() {
        assert_eq!(rows[i], c.tokens, "{what}: completion {i} token stream diverged");
    }
}

#[test]
fn solo_streamed_tokens_match_buffered_bitwise() {
    // (n, max_tokens, stop): plain, stop-token early finishes (re-fed feed
    // tokens must NOT be streamed), and a 40-row request spanning two
    // waves (row numbering must concatenate across waves).
    for (n, max_tokens, stop) in [(2usize, 6usize, None), (4, 8, Some(corpus::SEMI)), (40, 3, None)]
    {
        let r = req(1, n, max_tokens, stop);
        let buffered = engine().generate(&r).unwrap();

        let e = engine();
        let mut prep = e.prepare(&r).unwrap();
        let (handle, rx) = StreamHandle::channel(n * max_tokens + 8);
        prep.stream = Some(handle);
        let streamed = e.serve_prepared(prep).unwrap();

        assert_eq!(
            streamed.completions, buffered.completions,
            "streaming must not perturb the buffered result (n={n}, stop={stop:?})"
        );
        let rows = rows_from_events(rx, n);
        assert_rows_match(&rows, &buffered, &format!("solo n={n} stop={stop:?}"));
        let total: usize = rows.iter().map(|r| r.len()).sum();
        assert_eq!(e.metrics.streamed_tokens(), total, "metrics must count every event");
        assert_eq!(e.metrics.cancelled_requests(), 0);
    }
}

/// Serve scripted (release-point, request, sink) jobs through the batcher.
fn run_batched(
    engine: &Engine<NativeBackend>,
    jobs: Vec<(usize, GenerationRequest, Option<StreamHandle>)>,
) -> BTreeMap<u64, anyhow::Result<RequestResult>> {
    let out: Rc<RefCell<BTreeMap<u64, anyhow::Result<RequestResult>>>> =
        Rc::new(RefCell::new(BTreeMap::new()));
    let mut src: ScriptedSource<NativeBackend> = ScriptedSource::new();
    for (at, r, stream) in jobs {
        let id = r.id;
        let sink = Rc::clone(&out);
        src.push(
            at,
            BatchJob::Generate(
                r,
                stream,
                Box::new(move |res| {
                    sink.borrow_mut().insert(id, res);
                }),
            ),
        );
    }
    Batcher::new(engine, BatchConfig { window_us: 0, max_wave_rows: 0 }).run(&mut src);
    Rc::try_unwrap(out).ok().expect("sink still shared").into_inner()
}

#[test]
fn batched_streamed_tokens_match_buffered_bitwise() {
    // Two same-prefix streaming requests coalesce into ONE wave; each must
    // still see exactly its own rows, numbered request-locally, even with
    // stop-token finishes compacting inside the other's lane.
    let a = req(1, 2, 6, None);
    let b = req(2, 4, 8, Some(corpus::SEMI));
    let oracle_a = engine().generate(&a).unwrap();
    let oracle_b = engine().generate(&b).unwrap();

    let e = engine();
    let (ha, rxa) = StreamHandle::channel(64);
    let (hb, rxb) = StreamHandle::channel(64);
    let mut results = run_batched(&e, vec![(0, a, Some(ha)), (0, b, Some(hb))]);

    let got_a = results.remove(&1).unwrap().unwrap();
    let got_b = results.remove(&2).unwrap().unwrap();
    assert_eq!(got_a.completions, oracle_a.completions, "request 1 diverged");
    assert_eq!(got_b.completions, oracle_b.completions, "request 2 diverged");

    let rows_a = rows_from_events(rxa, 2);
    let rows_b = rows_from_events(rxb, 4);
    assert_rows_match(&rows_a, &oracle_a, "batched request 1");
    assert_rows_match(&rows_b, &oracle_b, "batched request 2");

    let counters = e.metrics.batch_counters();
    assert_eq!(counters.coalesced_requests, 2, "both must ride one wave");
    assert_eq!(counters.waves, 1);
    let total: usize = rows_a.iter().map(|r| r.len()).sum::<usize>()
        + rows_b.iter().map(|r| r.len()).sum::<usize>();
    assert_eq!(e.metrics.streamed_tokens(), total);
}

#[test]
fn cancel_mid_wave_frees_resources_and_preserves_survivors() {
    // Victim A and survivor B share a wave. A's client "disconnects" at a
    // scripted step boundary (the Inspect job flips the cancel flag the
    // HTTP worker would flip on a failed chunk write). A's lane must
    // compact out mid-wave; B must finish bit-for-bit as if undisturbed.
    let a = req(1, 2, 8, None);
    let b = req(2, 2, 8, None);
    let oracle_b = engine().generate(&b).unwrap();

    let e = engine();
    let (handle, rx) = StreamHandle::channel(64);
    let cancel_at_boundary = handle.canceller();
    let mut src: ScriptedSource<NativeBackend> = ScriptedSource::new();
    let out: Rc<RefCell<BTreeMap<u64, anyhow::Result<RequestResult>>>> =
        Rc::new(RefCell::new(BTreeMap::new()));
    for (r, stream) in [(a, Some(handle)), (b, None)] {
        let id = r.id;
        let sink = Rc::clone(&out);
        src.push(
            0,
            BatchJob::Generate(
                r,
                stream,
                Box::new(move |res| {
                    sink.borrow_mut().insert(id, res);
                }),
            ),
        );
    }
    src.push(
        3,
        BatchJob::Inspect(Box::new(move |_: &Engine<NativeBackend>| cancel_at_boundary.cancel())),
    );
    Batcher::new(&e, BatchConfig { window_us: 0, max_wave_rows: 0 }).run(&mut src);
    let mut results = Rc::try_unwrap(out).ok().expect("sink still shared").into_inner();

    // (a) the victim resolves as Cancelled with its 2 rows handed back
    let err = results.remove(&1).unwrap().unwrap_err();
    let c = err.downcast_ref::<Cancelled>().expect("victim must resolve as Cancelled");
    assert_eq!(c.freed_rows, 2);
    // it streamed its first draws but was cut off well short of its budget
    let events: Vec<StreamEvent> = rx.iter().collect();
    assert!(
        events.len() >= 2 && events.len() < 18,
        "victim should stream a little then stop, got {} events",
        events.len()
    );

    // (b) the survivor is bitwise-identical to an undisturbed run
    let got_b = results.remove(&2).unwrap().unwrap();
    assert_eq!(
        got_b.completions, oracle_b.completions,
        "survivor must be unaffected by the mid-wave cancellation"
    );
    assert_eq!(got_b.completions[0].tokens.len(), 8, "survivor ran its full budget");

    // (c) metrics account for the cancellation and the freed rows
    assert_eq!(e.metrics.cancelled_requests(), 1);
    let report = e.metrics_report();
    assert_eq!(report.f64_of("cancelled_requests"), 1.0);
    assert_eq!(report.f64_of("cancel_freed_rows"), 2.0);
    assert_eq!(e.metrics.streamed_tokens(), events.len());
    let counters = e.metrics.batch_counters();
    assert_eq!(counters.waves, 1);
    assert_eq!(counters.coalesced_requests, 2);
    assert_eq!(counters.peak_rows, 4, "the union held both requests before the cancel");

    // (d) resource hygiene: leases gone, pins dropped, node evictable
    let kv = e.kv.borrow().stats();
    assert_eq!(kv.sequences, 0, "cancelled lane must return its KV leases");
    e.kv.borrow().check_invariants().unwrap();
    e.cache.borrow().check_invariants(&e.kv.borrow()).unwrap();
    let evicted = {
        let mut kv = e.kv.borrow_mut();
        e.cache.borrow_mut().evict_lru(&mut kv)
    };
    assert!(evicted, "prefix node still pinned after the cancel");
    assert_eq!(e.kv.borrow().stats().used_blocks, 0);
}

#[test]
fn cancelling_a_parked_request_replies_and_leaves_no_trace() {
    // A cancels before it can ever join a wave: B fills the admission
    // first, and A's flag is already set when the batcher first looks at
    // it. The sweep must retire it from the parked queue (0 rows freed).
    let a = req(1, 2, 4, None);
    let b = req(2, 2, 4, None);
    let e = engine();
    let (handle, rx) = StreamHandle::channel(64);
    handle.canceller().cancel(); // client gone before admission
    let mut results = run_batched(&e, vec![(0, b, None), (0, a, Some(handle))]);

    // Depending on admission order A either never lanes (0 rows) or is cut
    // at the first boundary (2 rows); both must resolve as Cancelled.
    let err = results.remove(&1).unwrap().unwrap_err();
    let c = err.downcast_ref::<Cancelled>().expect("parked victim must resolve as Cancelled");
    assert!(c.freed_rows <= 2);
    assert!(results.remove(&2).unwrap().is_ok(), "the other request must be served");
    assert_eq!(e.metrics.cancelled_requests(), 1);
    drop(rx);

    let kv = e.kv.borrow().stats();
    assert_eq!(kv.sequences, 0);
    e.kv.borrow().check_invariants().unwrap();
    e.cache.borrow().check_invariants(&e.kv.borrow()).unwrap();
}

#[test]
fn solo_cancel_frees_lease_at_the_first_step_boundary() {
    // The non-batcher wave loop honors the same flag: a pre-cancelled
    // stream stops the request at the first boundary check with the KV
    // lease returned and the request counted as cancelled, not failed.
    let e = engine();
    let r = req(1, 2, 8, None);
    let mut prep = e.prepare(&r).unwrap();
    let (handle, rx) = StreamHandle::channel(64);
    handle.cancel();
    prep.stream = Some(handle);
    let err = e.serve_prepared(prep).unwrap_err();
    let c = err.downcast_ref::<Cancelled>().expect("must fail as Cancelled");
    assert_eq!(c.freed_rows, 2, "the whole wave's rows are handed back");

    // the prefix-end draws may land before the boundary check; nothing more
    let events: Vec<StreamEvent> = rx.iter().collect();
    assert!(events.len() <= 2, "at most the first draws, got {}", events.len());

    assert_eq!(e.metrics.cancelled_requests(), 1);
    let kv = e.kv.borrow().stats();
    assert_eq!(kv.sequences, 0, "lease must be returned on the cancel path");
    e.kv.borrow().check_invariants().unwrap();
    e.cache.borrow().check_invariants(&e.kv.borrow()).unwrap();
}

// ---------------------------------------------------------------------------
// HTTP end to end
// ---------------------------------------------------------------------------

struct TestServer {
    addr: std::net::SocketAddr,
    shutdown: std::sync::Arc<Shutdown>,
    thread: Option<std::thread::JoinHandle<()>>,
    client: std::sync::Arc<bifurcated_attn::server::EngineClient>,
}

impl TestServer {
    fn start() -> TestServer {
        let client = spawn_native_engine("pico-mq".into(), 0, EngineConfig::default()).unwrap();
        let server = build_server(std::sync::Arc::clone(&client));
        let shutdown = Shutdown::new();
        let flag = std::sync::Arc::clone(&shutdown);
        let thread = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", 4, Some(flag)).unwrap();
        });
        let addr = shutdown.wait_addr(Duration::from_secs(10)).expect("server never bound");
        TestServer { addr, shutdown, thread: Some(thread), client }
    }

    fn post(&self, path: &str, body: &str) -> ClientResponse {
        let mut s = connect_retry(self.addr, Duration::from_secs(5)).unwrap();
        send_request(&mut s, "POST", path, body).unwrap();
        ClientResponse::read_head(s).unwrap()
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.shutdown.trigger();
        if let Some(t) = self.thread.take() {
            // don't double-panic out of a failing test
            let _ = t.join();
        }
    }
}

/// Parse one `{"row":R,"token":T}` ndjson line.
fn parse_event(line: &str) -> Option<(usize, i32)> {
    let j = json::parse(line).ok()?;
    Some((j.get("row")?.as_usize()?, j.get("token")?.as_i64()? as i32))
}

#[test]
fn http_streaming_is_chunked_and_reconstructs_the_buffered_result() {
    let srv = TestServer::start();
    let n = 2usize;
    let body = format!(
        r#"{{"prompt":"{PROMPT}","n":{n},"max_tokens":4,"stop":null,"mode":"bifurcated","stream":true}}"#
    );
    let mut resp = srv.post("/generate", &body);
    assert_eq!(resp.status, 200);
    assert!(resp.is_chunked(), "streaming response must use chunked transfer");
    assert_eq!(resp.headers.get("content-type").map(String::as_str), Some("application/x-ndjson"));

    let mut rows: Vec<Vec<i32>> = vec![Vec::new(); n];
    let mut done: Option<json::Json> = None;
    let mut token_events = 0usize;
    while let Some(chunk) = resp.next_chunk().unwrap() {
        for line in chunk.lines().filter(|l| !l.is_empty()) {
            if let Some((row, tok)) = parse_event(line) {
                assert!(done.is_none(), "token events must precede the done chunk");
                rows[row].push(tok);
                token_events += 1;
            } else {
                let j = json::parse(line).expect("final chunk must be JSON");
                assert!(j.get("error").is_none(), "engine error: {j}");
                done = Some(j.get("done").expect("missing done payload").clone());
            }
        }
    }
    let done = done.expect("stream must end with a done chunk");
    assert_eq!(token_events, n * 4, "every sampled token arrives exactly once");

    // The streamed rows decode to exactly the buffered completions' text.
    let tok = TokenizerInfo::builtin();
    let comps = done.req("completions").as_arr().unwrap();
    assert_eq!(comps.len(), n);
    for (i, c) in comps.iter().enumerate() {
        assert_eq!(
            tok.decode(&rows[i]),
            c.str_of("text"),
            "completion {i}: streamed tokens must reconstruct the buffered text"
        );
    }

    let met = srv.client.metrics();
    assert!(met.f64_of("streamed_tokens") >= (n * 4) as f64);
    assert_eq!(met.f64_of("cancelled_requests"), 0.0);
}

#[test]
fn http_stream_query_flag_equals_body_flag() {
    let srv = TestServer::start();
    let body = format!(r#"{{"prompt":"{PROMPT}","n":1,"max_tokens":2,"stop":null}}"#);
    let mut resp = srv.post("/generate?stream=1", &body);
    assert_eq!(resp.status, 200);
    assert!(resp.is_chunked(), "?stream=1 must stream without a body flag");
    let text = resp.read_body().unwrap();
    assert!(text.contains("\"done\""), "missing done chunk in: {text}");

    // and without either flag the same route stays buffered
    let mut resp = srv.post("/generate", &body);
    assert_eq!(resp.status, 200);
    assert!(!resp.is_chunked(), "no flag means buffered");
    let j = json::parse(&resp.read_body().unwrap()).unwrap();
    assert_eq!(j.req("completions").as_arr().unwrap().len(), 1);
}

#[test]
fn sse_framing_carries_byte_identical_payloads() {
    // A fresh server per request means both requests are id 1, so the SSE
    // and ndjson runs draw identical tokens — every JSON payload (token
    // events and the terminal done object) must then match byte for byte;
    // only the framing differs.
    let body = format!(
        r#"{{"prompt":"{PROMPT}","n":2,"max_tokens":4,"stop":null,"mode":"bifurcated","stream":true}}"#
    );

    let srv = TestServer::start();
    let mut resp = srv.post("/generate", &body);
    assert_eq!(resp.status, 200);
    let ndjson: Vec<String> =
        resp.read_body().unwrap().lines().filter(|l| !l.is_empty()).map(String::from).collect();
    drop(srv);

    let srv = TestServer::start();
    let mut s = connect_retry(srv.addr, Duration::from_secs(5)).unwrap();
    send_request_with(&mut s, "POST", "/generate", &body, &[("Accept", "text/event-stream")])
        .unwrap();
    let mut resp = ClientResponse::read_head(s).unwrap();
    assert_eq!(resp.status, 200);
    assert!(resp.is_chunked(), "SSE responses still use chunked transfer");
    assert_eq!(resp.headers.get("content-type").map(String::as_str), Some("text/event-stream"));
    assert_eq!(resp.headers.get("cache-control").map(String::as_str), Some("no-cache"));
    let text = resp.read_body().unwrap();

    let frames: Vec<&str> = text.split("\n\n").filter(|f| !f.is_empty()).collect();
    assert_eq!(frames.len(), ndjson.len(), "one SSE frame per ndjson line:\n{text}");
    for (i, (frame, line)) in frames.iter().zip(&ndjson).enumerate() {
        let payload = if i == frames.len() - 1 {
            frame
                .strip_prefix("event: done\n")
                .expect("terminal frame must be `event: done`")
                .strip_prefix("data: ")
                .expect("terminal frame must carry a data line")
        } else {
            frame.strip_prefix("data: ").expect("token frames are bare data events")
        };
        assert_eq!(payload, line, "frame {i}: payload must be byte-identical to ndjson");
    }
    // sanity: the terminal payload really carries the buffered result
    let j = json::parse(ndjson.last().unwrap()).unwrap();
    assert_eq!(j.req("done").req("completions").as_arr().unwrap().len(), 2);
}

#[test]
fn http_disconnect_mid_stream_cancels_the_request() {
    let srv = TestServer::start();
    // A dropped client is only *observed* when a chunk write fails, so
    // give the request enough budget that plenty of writes follow the
    // disconnect. Retry a few times: a tiny request can win the race and
    // finish before the failed write lands.
    let body = format!(
        r#"{{"prompt":"{PROMPT}","n":8,"max_tokens":32,"stop":null,"mode":"bifurcated","stream":true}}"#
    );
    let mut cancelled = false;
    for _attempt in 0..10 {
        let mut resp = srv.post("/generate", &body);
        assert_eq!(resp.status, 200);
        let first = resp.next_chunk().unwrap();
        assert!(first.is_some(), "must stream at least one token before we hang up");
        drop(resp); // client vanishes mid-stream

        // the sweep lands at the next step boundary; give the engine a beat
        for _ in 0..100 {
            if srv.client.metrics().f64_of("cancelled_requests") >= 1.0 {
                cancelled = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        if cancelled {
            break;
        }
    }
    assert!(cancelled, "disconnect was never observed as a cancellation");

    // the engine remains healthy: a fresh request still completes, and the
    // cancelled request's rows were handed back
    let mut resp = srv.post(
        "/generate",
        &format!(r#"{{"prompt":"{PROMPT}","n":1,"max_tokens":2,"stop":null}}"#),
    );
    assert_eq!(resp.status, 200);
    let j = json::parse(&resp.read_body().unwrap()).unwrap();
    assert_eq!(j.req("completions").as_arr().unwrap().len(), 1);
    let met = srv.client.metrics();
    assert!(met.f64_of("cancel_freed_rows") >= 1.0, "freed rows must be accounted");
    assert_eq!(met.req("kv").f64_of("sequences"), 0.0, "no leaked decode leases");
}
