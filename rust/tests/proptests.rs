//! Property-based tests (propcheck-lite) on coordinator invariants:
//! KV manager accounting, block allocator, scheduler wave planning,
//! sampler bounds, pass@k estimator, reranker, and the cost model's
//! ordering guarantees (DESIGN.md §7).

use std::rc::Rc;

use bifurcated_attn::attention::{kv_io_bifurcated, kv_io_fused};
use bifurcated_attn::coordinator::request::{Completion, SamplingParams};
use bifurcated_attn::coordinator::{rerank_top_k, SamplerBatch, Scheduler, SchedulerConfig};
use bifurcated_attn::evalharness::pass_at_k;
use bifurcated_attn::kvcache::manager::KvManager;
use bifurcated_attn::kvcache::BlockAllocator;
use bifurcated_attn::prefixcache::{store, PrefixCache};
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::{Backend, HostTensor, NativeBackend};
use bifurcated_attn::util::propcheck::forall;
use bifurcated_attn::util::prng::Pcg;

#[test]
fn prop_block_allocator_never_leaks_or_double_frees() {
    forall(
        "block-allocator-invariants",
        150,
        |rng| {
            // a random sequence of alloc/share/release ops
            let ops: Vec<(u8, usize)> = (0..rng.below(40) + 5)
                .map(|_| (rng.below(3) as u8, rng.below(64) + 1))
                .collect();
            ops
        },
        |ops| {
            let mut a = BlockAllocator::new(64, 4);
            let mut live: Vec<Vec<usize>> = Vec::new();
            for &(op, arg) in ops {
                match op {
                    0 => {
                        if let Ok(blocks) = a.alloc(arg) {
                            live.push(blocks);
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = arg % live.len();
                            a.share(&live[i].clone());
                            live.push(live[i].clone());
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = arg % live.len();
                            let blocks = live.swap_remove(i);
                            a.release(&blocks);
                        }
                    }
                }
                a.check_invariants()?;
            }
            // drain: everything must come back
            for blocks in live.drain(..) {
                a.release(&blocks);
            }
            a.check_invariants()?;
            if a.used_blocks() != 0 {
                return Err(format!("{} blocks leaked", a.used_blocks()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_manager_accounting_exact() {
    forall(
        "kv-manager-invariants",
        100,
        |rng| {
            let n_groups = rng.below(4) + 1;
            let per_group: Vec<(usize, usize, bool)> = (0..n_groups)
                .map(|_| (rng.below(80) + 1, rng.below(16) + 1, rng.below(2) == 0))
                .collect();
            per_group
        },
        |groups| {
            let mut m = KvManager::new(1 << 20, 48, 8);
            let mut handles = Vec::new();
            for &(tokens, b, bifurcated) in groups {
                let mode = if bifurcated { DecodeMode::Bifurcated } else { DecodeMode::Fused };
                let ctx = match m.register_context(tokens, mode, b) {
                    Ok(c) => c,
                    Err(_) => continue, // explicit OOM is fine
                };
                let mut seqs = Vec::new();
                for _ in 0..b {
                    match m.start_sequence(ctx, 16) {
                        Ok(s) => seqs.push(s),
                        Err(_) => break,
                    }
                }
                m.check_invariants()?;
                handles.push((ctx, seqs));
            }
            // interleaved teardown: finish sequences in reverse group order
            for (ctx, seqs) in handles.into_iter().rev() {
                for s in seqs {
                    m.finish_sequence(s);
                }
                m.release_context(ctx);
                m.check_invariants()?;
            }
            let st = m.stats();
            if st.used_blocks != 0 || st.contexts != 0 || st.sequences != 0 {
                return Err(format!("leaked state: {st:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_manager_random_lease_release_oom_sequences() {
    // Arbitrary interleavings of register/lease/release/free — including
    // the fused b×-replica charging path and explicit OOM returns — must
    // keep the block accounting exact after every single operation.
    forall(
        "kv-random-op-sequences",
        120,
        |rng| {
            let n_ops = rng.below(60) + 10;
            (0..n_ops)
                .map(|_| (rng.below(4) as u8, rng.next_u64(), rng.below(64) + 1))
                .collect::<Vec<(u8, u64, usize)>>()
        },
        |ops| {
            // tiny capacity (32 blocks) so allocation failures are common
            let mut m = KvManager::new(16 * 1024, 64, 8);
            let mut ctxs: Vec<(u64, Vec<u64>)> = Vec::new();
            for &(op, r, amount) in ops {
                match op {
                    0 => {
                        // register: alternates modes; fused charges b× up front
                        let mode = if r % 2 == 0 { DecodeMode::Bifurcated } else { DecodeMode::Fused };
                        let b_planned = (r >> 1) as usize % 8 + 1;
                        if let Ok(c) = m.register_context(amount, mode, b_planned) {
                            ctxs.push((c, Vec::new()));
                        }
                    }
                    1 => {
                        // lease a sequence on a random live context
                        if !ctxs.is_empty() {
                            let i = r as usize % ctxs.len();
                            if let Ok(s) = m.start_sequence(ctxs[i].0, amount % 16 + 1) {
                                ctxs[i].1.push(s);
                            }
                        }
                    }
                    2 => {
                        // finish the newest sequence of a random context
                        if !ctxs.is_empty() {
                            let i = r as usize % ctxs.len();
                            if let Some(s) = ctxs[i].1.pop() {
                                m.finish_sequence(s);
                            }
                        }
                    }
                    _ => {
                        // release some fully-drained context, if one exists
                        if let Some(i) = ctxs.iter().position(|(_, seqs)| seqs.is_empty()) {
                            let (c, _) = ctxs.remove(i);
                            m.release_context(c);
                        }
                    }
                }
                m.check_invariants()?;
            }
            // full teardown must return the manager to exactly zero
            for (c, seqs) in ctxs {
                for s in seqs {
                    m.finish_sequence(s);
                }
                m.release_context(c);
                m.check_invariants()?;
            }
            let st = m.stats();
            if st.used_blocks != 0 || st.contexts != 0 || st.sequences != 0 {
                return Err(format!("leaked state after teardown: {st:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fused_registration_charges_exactly_b_replicas() {
    // Direct property on the fused charging path: for any (tokens, b) that
    // fits, fused uses exactly b× the blocks of bifurcated — and leasing
    // never changes context storage (a lease round-trip returns usage to
    // the post-register level).
    forall(
        "fused-bx-charging",
        200,
        |rng| (rng.below(60) + 1, rng.below(12) + 1),
        |&(tokens, b)| {
            let mut bif = KvManager::new(1 << 20, 64, 8);
            let mut fus = KvManager::new(1 << 20, 64, 8);
            let cb = bif
                .register_context(tokens, DecodeMode::Bifurcated, b)
                .map_err(|e| format!("bifurcated register: {e:?}"))?;
            let one = bif.stats().used_blocks;
            let cf = fus
                .register_context(tokens, DecodeMode::Fused, b)
                .map_err(|e| format!("fused register: {e:?}"))?;
            // fused charged for b copies of the context token span
            let expect = (tokens * b).div_ceil(8);
            if fus.stats().used_blocks != expect {
                return Err(format!(
                    "fused blocks {} != ceil({tokens}*{b}/8) = {expect}",
                    fus.stats().used_blocks
                ));
            }
            if b == 1 && fus.stats().used_blocks != one {
                return Err("b=1 fused should equal bifurcated".into());
            }
            // lease round-trip: decode slots are extra, context storage is
            // untouched, and finishing returns exactly to post-register
            let seqs: Vec<_> = (0..b)
                .map(|_| bif.start_sequence(cb, 16))
                .collect::<Result<_, _>>()
                .map_err(|e| format!("lease: {e:?}"))?;
            let per_seq = 16usize.div_ceil(8);
            if bif.stats().used_blocks != one + b * per_seq {
                return Err(format!(
                    "leases changed context storage: {} != {one} + {b}*{per_seq}",
                    bif.stats().used_blocks
                ));
            }
            for s in seqs {
                bif.finish_sequence(s);
            }
            if bif.stats().used_blocks != one {
                return Err("finishing leases did not restore post-register usage".into());
            }
            bif.check_invariants()?;
            fus.check_invariants()?;
            bif.release_context(cb);
            fus.release_context(cf);
            if bif.stats().used_blocks != 0 || fus.stats().used_blocks != 0 {
                return Err("release leaked blocks".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_prefix_cache_eviction_respects_pins_and_accounting() {
    // Random interleavings of insert / pin / unpin / evict over a small
    // prefix cache + KV manager: after every single operation the tree,
    // cache, and block accounting invariants must hold, a pinned node
    // must never be evicted, and a full drain must return every block.
    let be = NativeBackend::preset("pico-mq", 0).unwrap();
    let cfg = be.cfg().clone();
    forall(
        "prefix-cache-ops",
        60,
        |rng| {
            (0..rng.below(50) + 10)
                .map(|_| (rng.below(6) as u8, rng.next_u64()))
                .collect::<Vec<(u8, u64)>>()
        },
        |ops| {
            // tiny capacity (24 blocks of 8 tokens) so KV pressure is real
            let bpt = cfg.kv_bytes_per_token();
            let mut kv = KvManager::new(24 * 8 * bpt, bpt, 8);
            // every entry's resident K_c/V_c is the same padded size here,
            // so a 3-entry byte budget under a 4-entry budget makes the
            // byte limit the binding constraint
            let entry_bytes = 2 * cfg.l * cfg.g * cfg.m_c_max * cfg.k * 4;
            let mut cache: PrefixCache<NativeBackend> =
                PrefixCache::with_budgets(4, 3 * entry_bytes);
            let mut pinned: Vec<usize> = Vec::new();
            for &(op, r) in ops {
                match op {
                    0 | 1 | 2 => {
                        // insert a random prompt unless it is already fully
                        // cached (the engine's full-hit path never inserts)
                        let len = (r as usize % 12) + 1;
                        let tokens: Vec<i32> =
                            (0..len).map(|i| (((r >> (i % 16)) & 3) + 1) as i32).collect();
                        let full_hit =
                            cache.lookup(&tokens).is_some_and(|h| h.matched == tokens.len());
                        if !full_hit && cache.make_room(&mut kv, entry_bytes) {
                            if let Ok(id) = kv.register_cached_context(tokens.len()) {
                                let kc = Rc::new(HostTensor::zeros_f32(&[
                                    cfg.l, cfg.g, cfg.m_c_max, cfg.k,
                                ]));
                                let vc = Rc::new(HostTensor::zeros_f32(&[
                                    cfg.l, cfg.g, cfg.m_c_max, cfg.k,
                                ]));
                                let ctx =
                                    Rc::new(be.upload_context(&kc, &vc, tokens.len()).unwrap());
                                cache.insert(&tokens, vec![0.0; cfg.vocab], kc, vc, ctx, id);
                            }
                        }
                    }
                    3 => {
                        let ids = cache.entry_ids();
                        if !ids.is_empty() {
                            let id = ids[r as usize % ids.len()];
                            cache.pin(id);
                            pinned.push(id);
                        }
                    }
                    4 => {
                        if !pinned.is_empty() {
                            let i = r as usize % pinned.len();
                            let id = pinned.swap_remove(i);
                            cache.unpin(id);
                        }
                    }
                    _ => {
                        cache.evict_lru(&mut kv);
                    }
                }
                kv.check_invariants()?;
                cache.check_invariants(&kv)?;
                for &id in &pinned {
                    if !cache.contains(id) {
                        return Err(format!("pinned node {id} was evicted"));
                    }
                }
            }
            // drain: unpin everything, evict everything, no block leaks
            for id in std::mem::take(&mut pinned) {
                cache.unpin(id);
            }
            while cache.evict_lru(&mut kv) {}
            if !cache.is_empty() {
                return Err("unpinned entries survived a full drain".into());
            }
            let st = kv.stats();
            if st.used_blocks != 0 || st.contexts != 0 {
                return Err(format!("leaked KV state after drain: {st:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scheduler_waves_partition_any_n() {
    let s = Scheduler::new(SchedulerConfig::default(), vec![1, 2, 4, 8, 16, 32]);
    forall(
        "waves-partition",
        300,
        |rng| rng.below(500) + 1,
        |&n| {
            let waves = s.plan_waves(n);
            let total: usize = waves.iter().map(|w| w.live).sum();
            if total != n {
                return Err(format!("waves cover {total} != n {n}"));
            }
            for w in &waves {
                if w.live > w.bucket {
                    return Err(format!("overfull wave {w:?}"));
                }
            }
            // padding waste bounded: only the final wave may be padded
            let padded = waves.iter().filter(|w| w.live < w.bucket).count();
            if padded > 1 {
                return Err(format!("{padded} padded waves"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sampler_respects_max_tokens_and_stop() {
    forall(
        "sampler-bounds",
        80,
        |rng| {
            (
                rng.below(8) + 1,          // b
                rng.below(6) + 1,          // max_tokens
                rng.next_u64(),            // seed
                rng.below(2) == 0,         // with stop token
            )
        },
        |&(b, max_tokens, seed, with_stop)| {
            let vocab = 16;
            let params = SamplingParams {
                n: b,
                temperature: 1.0,
                top_p: 1.0,
                max_tokens,
                stop_token: if with_stop { Some(3) } else { None },
                seed,
                mode: None,
                deadline_ms: None,
            };
            let mut sb = SamplerBatch::new(b, params, vocab, seed);
            let mut rng = Pcg::new(seed);
            let logits: Vec<f32> = (0..vocab).map(|_| rng.f32()).collect();
            sb.first_tokens(&logits);
            let mut guard = 0;
            while !sb.all_finished() {
                let step_logits: Vec<f32> = (0..vocab * b).map(|_| rng.f32()).collect();
                sb.step(&step_logits);
                guard += 1;
                if guard > max_tokens + 2 {
                    return Err("sampler failed to terminate".into());
                }
            }
            let comps = sb.into_completions(|_| String::new());
            for c in &comps {
                if c.tokens.len() > max_tokens {
                    return Err(format!("{} tokens > max {max_tokens}", c.tokens.len()));
                }
                if c.finished_by_stop && *c.tokens.last().unwrap() != 3 {
                    return Err("stop-flag without stop token".into());
                }
                if !c.mean_logp().is_finite() {
                    return Err("non-finite logp".into());
                }
                if c.mean_logp() > 0.0 {
                    return Err(format!("positive mean logp {}", c.mean_logp()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pass_at_k_bounds_and_monotonicity() {
    forall(
        "pass@k-bounds",
        500,
        |rng| {
            let n = rng.below(40) + 1;
            let c = rng.below(n + 1);
            let k = rng.below(n) + 1;
            (n, c, k)
        },
        |&(n, c, k)| {
            let p = pass_at_k(n, c, k);
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("p={p} out of range"));
            }
            if c > 0 && k < n {
                let p2 = pass_at_k(n, c, k + 1);
                if p2 + 1e-12 < p {
                    return Err(format!("not monotone in k: {p} -> {p2}"));
                }
            }
            if c < n {
                let p3 = pass_at_k(n, c + 1, k);
                if p3 + 1e-12 < p {
                    return Err(format!("not monotone in c: {p} -> {p3}"));
                }
            }
            // pass@n with any correct == 1
            if c > 0 && (pass_at_k(n, c, n) - 1.0).abs() > 1e-12 {
                return Err("pass@n != 1 with c>0".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_reranker_output_sorted_unique_bounded() {
    forall(
        "reranker-invariants",
        200,
        |rng| {
            let n = rng.below(30) + 1;
            let comps: Vec<(usize, f64, usize)> = (0..n)
                .map(|_| (rng.below(8), -(rng.f64() * 5.0), rng.below(6) + 1))
                .collect();
            let k = rng.below(6) + 1;
            (comps, k)
        },
        |(comps, k)| {
            let completions: Vec<Completion> = comps
                .iter()
                .map(|&(text_id, logp, len)| Completion {
                    text: format!("t{text_id};"),
                    tokens: vec![2; len],
                    sum_logp: logp * len as f64,
                    finished_by_stop: true,
                })
                .collect();
            let top = rerank_top_k(&completions, *k);
            if top.len() > *k {
                return Err("more than k results".into());
            }
            let texts: std::collections::BTreeSet<_> = top.iter().map(|c| &c.text).collect();
            if texts.len() != top.len() {
                return Err("duplicates in output".into());
            }
            for w in top.windows(2) {
                if w[0].mean_logp() < w[1].mean_logp() - 1e-12 {
                    return Err("not sorted by mean_logp desc".into());
                }
            }
            // best item is the global max over the deduped set
            if let Some(first) = top.first() {
                let global = completions
                    .iter()
                    .map(|c| c.mean_logp())
                    .fold(f64::NEG_INFINITY, f64::max);
                if first.mean_logp() + 1e-12 < global {
                    return Err("top-1 is not the argmax".into());
                }
            }
            Ok(())
        },
    );
}

fn rand_tensor(rng: &mut Pcg) -> HostTensor {
    let dims: Vec<usize> = (0..rng.below(3) + 1).map(|_| rng.below(4) + 1).collect();
    let numel: usize = dims.iter().product();
    HostTensor::from_f32((0..numel).map(|_| rng.f32() * 4.0 - 2.0).collect(), &dims)
}

fn rand_records(rng: &mut Pcg) -> Vec<store::NodeRecord> {
    (0..rng.below(5))
        .map(|_| store::NodeRecord {
            tokens: (0..rng.below(6) + 1).map(|_| rng.below(4096) as i32).collect(),
            last_used: rng.next_u64() % 1000,
            logits: (0..rng.below(8)).map(|_| rng.f32()).collect(),
            kc: rand_tensor(rng),
            vc: rand_tensor(rng),
        })
        .collect()
}

#[test]
fn prop_snapshot_roundtrip_is_bit_exact() {
    // Any record set survives encode → frame → decode with bit-identical
    // tokens, logits, tensors, and LRU stamps — and a snapshot written
    // under one model fingerprint restores nothing under another.
    forall(
        "snapshot-roundtrip",
        120,
        |rng| rand_records(rng),
        |recs| {
            let payloads: Vec<Vec<u8>> = recs
                .iter()
                .map(|r| store::encode_record(&r.tokens, &r.logits, &r.kc, &r.vc, r.last_used))
                .collect();
            let image = store::encode_snapshot("prop-fp", &payloads);
            let (got, stats) = store::decode_snapshot(&image, "prop-fp");
            if stats.dropped != 0 || stats.checksum_failures != 0 {
                return Err(format!("clean image lost records: {stats:?}"));
            }
            if stats.nodes as usize != recs.len() {
                return Err(format!("stats.nodes {} != {} records", stats.nodes, recs.len()));
            }
            if &got != recs {
                return Err("decoded records differ from what was written".into());
            }
            let (other, _) = store::decode_snapshot(&image, "other-model");
            if !other.is_empty() {
                return Err("fingerprint mismatch must restore nothing".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_snapshot_decode_survives_truncation_and_bit_flips() {
    // Arbitrarily truncated and/or bit-flipped images must never panic
    // the decoder and must never yield a record that was not written
    // verbatim — the per-record CRC gate admits no mutated bytes.
    forall(
        "snapshot-fuzz",
        250,
        |rng| {
            let recs = rand_records(rng);
            let payloads: Vec<Vec<u8>> = recs
                .iter()
                .map(|r| store::encode_record(&r.tokens, &r.logits, &r.kc, &r.vc, r.last_used))
                .collect();
            let mut image = store::encode_snapshot("prop-fp", &payloads);
            if rng.below(2) == 0 {
                let cut = rng.below(image.len() + 1);
                image.truncate(cut);
            }
            if !image.is_empty() {
                for _ in 0..rng.below(4) {
                    let i = rng.below(image.len());
                    image[i] ^= 1u8 << rng.below(8);
                }
            }
            (recs, image)
        },
        |(recs, image)| {
            let (got, stats) = store::decode_snapshot(image, "prop-fp");
            if got.len() != stats.nodes as usize {
                return Err(format!("stats.nodes {} != {} records", stats.nodes, got.len()));
            }
            for g in &got {
                if !recs.iter().any(|r| r == g) {
                    return Err("decode yielded a record that was never written".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bifurcated_io_dominates_fused() {
    // Eq. 5 >= Eq. 6 for every shape; equality iff b == 1 or m_c == 0.
    forall(
        "eq5-dominates-eq6",
        500,
        |rng| {
            (
                rng.below(256) + 1,
                rng.below(16) + 1,
                [8, 16, 32, 64, 128][rng.below(5)],
                rng.below(20_000),
                rng.below(512),
            )
        },
        |&(b, g, k, mc, md)| {
            let fused = kv_io_fused(b, g, k, mc, md);
            let bif = kv_io_bifurcated(b, g, k, mc, md);
            if bif > fused {
                return Err(format!("bifurcated {bif} > fused {fused}"));
            }
            let expect_equal = b == 1 || mc == 0;
            if expect_equal && bif != fused {
                return Err("should be equal at b=1 or mc=0".into());
            }
            if !expect_equal && md > 0 && bif == fused && mc > 0 {
                return Err("strict improvement expected".into());
            }
            Ok(())
        },
    );
}
