//! Coalescing parity: N concurrent same-prefix `/generate` requests
//! served by the continuous batcher must produce completions
//! **bitwise-identical** to the same N requests issued serially (same
//! per-request ids/seeds), across wave widths {1, 2, 8} and with
//! mid-wave join and early detach exercised deterministically through a
//! [`ScriptedSource`].

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use bifurcated_attn::coordinator::batcher::{BatchConfig, BatchJob, Batcher, ScriptedSource};
use bifurcated_attn::coordinator::{
    Completion, Engine, EngineConfig, GenerationRequest, ModePolicy, RequestResult, SamplingParams,
};
use bifurcated_attn::corpus;
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::NativeBackend;

const PROMPT: &str = "10+2=12;11+3=14;12+4=";

fn engine() -> Engine<NativeBackend> {
    Engine::native("pico-mq", 0, EngineConfig::default()).unwrap()
}

fn req(
    id: u64,
    n: usize,
    max_tokens: usize,
    stop: Option<i32>,
) -> GenerationRequest {
    GenerationRequest {
        id,
        prompt: PROMPT.into(),
        params: SamplingParams {
            n,
            temperature: 0.8,
            top_p: 0.95,
            max_tokens,
            stop_token: stop,
            seed: id,
            mode: Some(ModePolicy::Force(DecodeMode::Bifurcated)),
            deadline_ms: None,
        },
    }
}

/// Serve `jobs` (scripted release point, request) through the batcher on
/// `engine`; returns results keyed by request id.
fn run_batched(
    engine: &Engine<NativeBackend>,
    jobs: Vec<(usize, GenerationRequest)>,
) -> BTreeMap<u64, RequestResult> {
    let out: Rc<RefCell<BTreeMap<u64, RequestResult>>> = Rc::new(RefCell::new(BTreeMap::new()));
    let mut src: ScriptedSource<NativeBackend> = ScriptedSource::new();
    for (at, r) in jobs {
        let id = r.id;
        let sink = Rc::clone(&out);
        src.push(
            at,
            BatchJob::Generate(
                r,
                None,
                Box::new(move |res| {
                    sink.borrow_mut().insert(id, res.expect("batched request failed"));
                }),
            ),
        );
    }
    Batcher::new(engine, BatchConfig { window_us: 0, max_wave_rows: 0 }).run(&mut src);
    Rc::try_unwrap(out).ok().expect("sink still shared").into_inner()
}

/// Serial oracle: the same requests one by one on a fresh engine.
fn run_serial(reqs: &[GenerationRequest]) -> BTreeMap<u64, RequestResult> {
    let e = engine();
    reqs.iter().map(|r| (r.id, e.generate(r).unwrap())).collect()
}

fn completions(r: &RequestResult) -> &[Completion] {
    &r.completions
}

#[test]
fn concurrent_equals_serial_across_widths() {
    // stop disabled so every lane deterministically runs all its steps
    // (the wave-sharing counters below depend on it); stop-token behavior
    // under coalescing is pinned by stop_token_parity.
    for width in [1usize, 2, 8] {
        let reqs: Vec<GenerationRequest> =
            (1..=width as u64).map(|id| req(id, 2, 6, None)).collect();
        let serial = run_serial(&reqs);

        let e = engine();
        let batched = run_batched(&e, reqs.iter().map(|r| (0, r.clone())).collect());

        assert_eq!(batched.len(), width);
        for (id, b) in &batched {
            let s = &serial[id];
            assert_eq!(
                completions(b),
                completions(s),
                "width {width}: request {id} diverged from serial execution"
            );
            assert_eq!(b.mode_used, DecodeMode::Bifurcated);
        }
        let counters = e.metrics.batch_counters();
        assert_eq!(counters.batched_requests, width);
        if width > 1 {
            assert_eq!(
                counters.coalesced_requests, width,
                "width {width}: all requests must share the wave"
            );
            assert_eq!(counters.waves, 1, "width {width}: one union wave serves everyone");
            assert_eq!(counters.peak_rows, 2 * width, "n=2 rows per request");
        }
        // KV clean after the run: only the cached node's context remains.
        let kv = e.kv.borrow().stats();
        assert_eq!(kv.sequences, 0);
        assert_eq!(kv.contexts, kv.cached_contexts);
        e.kv.borrow().check_invariants().unwrap();
        e.cache.borrow().check_invariants(&e.kv.borrow()).unwrap();
    }
}

#[test]
fn stop_token_parity_under_coalescing() {
    // Stop-token finishes inside a lane (finished rows keep feeding their
    // last token, exactly like the solo loop) must not disturb anyone.
    let reqs: Vec<GenerationRequest> =
        (1..=4u64).map(|id| req(id, 4, 8, Some(corpus::SEMI))).collect();
    let serial = run_serial(&reqs);
    let e = engine();
    let batched = run_batched(&e, reqs.iter().map(|r| (0, r.clone())).collect());
    for (id, b) in &batched {
        assert_eq!(
            completions(b),
            completions(&serial[id]),
            "request {id} diverged with stop tokens in play"
        );
    }
    assert_eq!(e.metrics.batch_counters().batched_requests, 4);
}

#[test]
fn mid_wave_join_is_bitwise_transparent() {
    // A runs a long wave (stop disabled -> exactly max_tokens tokens); B
    // is released 3 step-boundaries in and joins mid-wave with ragged
    // decode positions. Both must match the serial oracle bit for bit.
    let a = req(1, 2, 8, None);
    let b = req(2, 2, 8, None);
    let serial = run_serial(&[a.clone(), b.clone()]);

    let e = engine();
    let batched = run_batched(&e, vec![(0, a), (4, b)]);
    for id in [1u64, 2] {
        assert_eq!(
            completions(&batched[&id]),
            completions(&serial[&id]),
            "request {id} diverged under mid-wave join"
        );
    }
    let counters = e.metrics.batch_counters();
    assert_eq!(counters.mid_wave_joins, 1, "B must join after A has stepped");
    assert_eq!(counters.coalesced_requests, 2);
    assert_eq!(counters.waves, 1);
    // B's rows were fresh while A was mid-decode: the join ran ragged
    // positions, and the union peaked at both requests' rows.
    assert_eq!(counters.peak_rows, 4);
}

#[test]
fn early_detach_compacts_without_disturbing_survivors() {
    // A finishes after 2 tokens and detaches; B decodes to 8. B's rows
    // survive the compaction rebuild bit-for-bit.
    let a = req(1, 2, 2, None);
    let b = req(2, 2, 8, None);
    let serial = run_serial(&[a.clone(), b.clone()]);

    let e = engine();
    let batched = run_batched(&e, vec![(0, a), (0, b)]);
    for id in [1u64, 2] {
        assert_eq!(
            completions(&batched[&id]),
            completions(&serial[&id]),
            "request {id} diverged under early detach"
        );
    }
    assert_eq!(batched[&1].completions[0].tokens.len(), 2);
    assert_eq!(batched[&2].completions[0].tokens.len(), 8);
    let counters = e.metrics.batch_counters();
    assert_eq!(counters.waves, 1);
    assert_eq!(counters.coalesced_requests, 2);
    // After A detached the wave kept stepping at B's width only.
    assert_eq!(counters.peak_rows, 4);
}

#[test]
fn width_cap_defers_joins_and_multi_wave_requests_sequence() {
    // A needs two waves (n = 40 > the largest bucket 32); B (n = 4) cannot
    // fit next to A's first 32-row wave, so it waits and then shares the
    // second wave with A's 8-row tail. Everyone still matches serial.
    let a = req(1, 40, 3, None);
    let b = req(2, 4, 3, None);
    let serial = run_serial(&[a.clone(), b.clone()]);

    let e = engine();
    let batched = run_batched(&e, vec![(0, a), (0, b)]);
    for id in [1u64, 2] {
        assert_eq!(
            completions(&batched[&id]),
            completions(&serial[&id]),
            "request {id} diverged under the width cap"
        );
    }
    assert_eq!(batched[&1].completions.len(), 40);
    assert_eq!(batched[&1].timing.waves, 2);
    let counters = e.metrics.batch_counters();
    // One union wave hosted both of A's waves and B's.
    assert_eq!(counters.waves, 1);
    assert_eq!(counters.peak_rows, 32, "the cap held the union at the largest bucket");
    assert_eq!(counters.coalesced_requests, 2, "A's tail and B shared steps");
}

#[test]
fn batched_timing_reports_cache_and_coalescing() {
    let e = engine();
    let batched = run_batched(&e, vec![(0, req(1, 2, 4, None)), (0, req(2, 2, 4, None))]);
    // First request was cold (it built the node), second warm.
    let prompt_len = e.tokenize_prompt(PROMPT).unwrap().len();
    assert_eq!(batched[&1].timing.cache_hit_tokens, 0);
    assert!(batched[&1].timing.upload_bytes > 0);
    assert_eq!(batched[&2].timing.cache_hit_tokens, prompt_len);
    assert_eq!(batched[&2].timing.upload_bytes, 0, "warm join reuses the resident context");
    for id in [1u64, 2] {
        assert_eq!(batched[&id].timing.coalesced_peak_rows, 4);
        assert_eq!(batched[&id].timing.decode_steps, 3, "first token + 3 steps = 4 tokens");
    }
}
