"""AOT compile path: train the pico serving models, lower every entry point
to HLO *text*, and emit ``artifacts/`` for the rust runtime.

HLO text (not serialized ``HloModuleProto``) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs (all under ``--out``, default ``../artifacts``):

    manifest.json                     everything the rust side needs
    hlo/<variant>.prefill.hlo.txt
    hlo/<variant>.decode.<mode>.b<b>.hlo.txt
    hlo/<variant>.train_step.hlo.txt  (scaling family)
    hlo/<variant>.eval_loss.hlo.txt
    weights/<variant>.bin             flat f32 params in param_spec order

Run via ``make artifacts``; python never runs again after this.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus
from .configs import (BATCH_BUCKETS, DECODE_MODES, PICO_TRAIN_BATCH,
                      SCALING_VARIANTS, SERVING_VARIANTS, TRAIN_BATCH, VOCAB,
                      ModelConfig)
from . import model as M

assert VOCAB == corpus.VOCAB_SIZE, "configs.VOCAB must match the tokenizer"


# --------------------------------------------------------------------------
# HLO text lowering
# --------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> Dict:
    """jit-lower ``fn`` at the example shapes and write HLO text.
    Returns a small descriptor (arg shapes/dtypes) for the manifest."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    args_desc = [
        {"shape": list(np.shape(a)), "dtype": str(np.asarray(a).dtype)}
        for a in example_args
    ]
    return {"file": os.path.relpath(path, os.path.dirname(os.path.dirname(path))),
            "args": args_desc, "bytes": len(text)}


def shape_struct(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# --------------------------------------------------------------------------
# Weights I/O — raw little-endian f32, concatenated in param_spec order.
# --------------------------------------------------------------------------


def write_weights(path: str, cfg: ModelConfig, params: Dict[str, jax.Array]):
    flat = M.flatten_params(cfg, params)
    buf = b"".join(np.asarray(a, dtype="<f4").tobytes() for a in flat)
    with open(path, "wb") as f:
        f.write(buf)
    return len(buf)


# --------------------------------------------------------------------------
# Pico training (serving family): learn the arithmetic grammar well enough
# that temperature sampling lands in the pass@n-improves-with-n regime.
# --------------------------------------------------------------------------


def train_pico(cfg: ModelConfig, steps: int, seed: int = 0, lr: float = 1.5e-3):
    rng = np.random.default_rng(seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    m = M.zeros_like_params(cfg)
    v = M.zeros_like_params(cfg)
    step_fn = M.make_jitted_train(cfg, lr=lr)
    t0 = time.time()
    loss = float("nan")
    for i in range(1, steps + 1):
        batch = corpus.training_batch(rng, PICO_TRAIN_BATCH, cfg.seq_len)
        params, m, v, loss = step_fn(params, m, v, jnp.float32(i), batch)
        if i % max(1, steps // 8) == 0:
            print(f"    [{cfg.name}] step {i}/{steps} loss={float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    val = corpus.training_batch(np.random.default_rng(10_000), PICO_TRAIN_BATCH, cfg.seq_len)
    val_loss = float(jax.jit(lambda p, b: M.eval_loss(p, cfg, b))(params, val))
    return params, float(loss), val_loss


def greedy_accuracy(cfg: ModelConfig, params, n_tasks: int = 40, seed: int = 7) -> float:
    """Greedy-decode accuracy on held-out tasks (manifest metadata only)."""
    rng = np.random.default_rng(seed)
    fwd = jax.jit(lambda p, t, ln: M.forward_full(p, cfg, t, ln)[0])
    hits = 0
    for _ in range(n_tasks):
        a = int(rng.integers(0, corpus.MAX_OPERAND + 1))
        b = int(rng.integers(0, corpus.MAX_OPERAND + 1))
        prompt = corpus.make_prompt(rng, n_shots=4, a=a, b=b)
        ids = [corpus.BOS] + corpus.encode(prompt)
        out = []
        for _ in range(6):
            toks = np.asarray([ids], dtype=np.int32)
            logits = fwd(params, toks, len(ids))
            nxt = int(jnp.argmax(logits[0, len(ids) - 1]))
            ids.append(nxt)
            out.append(nxt)
            if nxt == corpus.SEMI:
                break
        if corpus.check_completion(a, b, corpus.decode_ids(out)):
            hits += 1
    return hits / n_tasks


# --------------------------------------------------------------------------
# Entry-point wrappers with flat (manifest-ordered) signatures.
# Scalars travel as shape-[1] i32/f32 arrays — trivially constructed as
# literals on the rust side.
# --------------------------------------------------------------------------


def make_prefill_fn(cfg: ModelConfig):
    n_params = len(M.param_spec(cfg))

    def fn(*args):
        params = M.unflatten_params(cfg, list(args[:n_params]))
        tokens, length = args[n_params], args[n_params + 1]
        logits, kc, vc = M.prefill(params, cfg, tokens, length[0])
        return logits, kc, vc

    return fn


def make_decode_fn(cfg: ModelConfig, mode: str):
    n_params = len(M.param_spec(cfg))

    def fn(*args):
        params = M.unflatten_params(cfg, list(args[:n_params]))
        tokens, d_pos, m_c_len, kc, vc, kd, vd = args[n_params:]
        return M.decode_step(params, cfg, mode, tokens, d_pos[0], m_c_len[0],
                             kc, vc, kd, vd, interpret=True)

    return fn


def make_train_fn(cfg: ModelConfig, lr: float):
    spec = M.param_spec(cfg)
    P = len(spec)

    def fn(*args):
        params = M.unflatten_params(cfg, list(args[:P]))
        m = M.unflatten_params(cfg, list(args[P:2 * P]))
        v = M.unflatten_params(cfg, list(args[2 * P:3 * P]))
        step, batch = args[3 * P], args[3 * P + 1]
        p2, m2, v2, loss = M.train_step(params, m, v, step[0], batch, cfg, lr=lr)
        out = tuple(M.flatten_params(cfg, p2)) + tuple(M.flatten_params(cfg, m2)) \
            + tuple(M.flatten_params(cfg, v2)) + (jnp.reshape(loss, (1,)),)
        return out

    return fn


def make_eval_fn(cfg: ModelConfig):
    P = len(M.param_spec(cfg))

    def fn(*args):
        params = M.unflatten_params(cfg, list(args[:P]))
        batch = args[P]
        return (jnp.reshape(M.eval_loss(params, cfg, batch), (1,)),)

    return fn


def param_structs(cfg: ModelConfig):
    return [shape_struct(s) for _, s in M.param_spec(cfg)]


# --------------------------------------------------------------------------
# Main build
# --------------------------------------------------------------------------


def build_serving(outdir: str, steps: int, buckets, quick: bool) -> List[Dict]:
    entries = []
    for cfg in SERVING_VARIANTS:
        print(f"[aot] training {cfg.name} ({cfg.param_count():,} params, "
              f"g={cfg.g}, {steps} steps)", flush=True)
        params, train_loss, val_loss = train_pico(cfg, steps)
        acc = greedy_accuracy(cfg, params) if not quick else -1.0
        print(f"[aot]   {cfg.name}: train_loss={train_loss:.4f} "
              f"val_loss={val_loss:.4f} greedy_acc={acc:.2f}", flush=True)

        wpath = os.path.join(outdir, "weights", f"{cfg.name}.bin")
        nbytes = write_weights(wpath, cfg, params)

        l, g, k, mc, md = cfg.l, cfg.g, cfg.k, cfg.m_c_max, cfg.m_d_max
        pstructs = param_structs(cfg)
        i32_1 = shape_struct((1,), jnp.int32)

        art: Dict = {"decode": {m: {} for m in DECODE_MODES}}
        path = os.path.join(outdir, "hlo", f"{cfg.name}.prefill.hlo.txt")
        art["prefill"] = lower_to_file(
            make_prefill_fn(cfg),
            pstructs + [shape_struct((1, mc), jnp.int32), i32_1],
            path,
        )
        for mode in DECODE_MODES:
            for b in buckets:
                kc_shape = (l, g, mc, k) if mode == "bifurcated" else (l, b, g, mc, k)
                example = pstructs + [
                    shape_struct((b,), jnp.int32),   # tokens
                    i32_1,                            # d_pos
                    i32_1,                            # m_c_len
                    shape_struct(kc_shape),           # kc
                    shape_struct(kc_shape),           # vc
                    shape_struct((l, b, g, md, k)),   # kd
                    shape_struct((l, b, g, md, k)),   # vd
                ]
                path = os.path.join(outdir, "hlo", f"{cfg.name}.decode.{mode}.b{b}.hlo.txt")
                art["decode"][mode][str(b)] = lower_to_file(make_decode_fn(cfg, mode), example, path)
                print(f"[aot]   lowered {cfg.name} decode {mode} b={b}", flush=True)

        entries.append({
            "name": cfg.name,
            "config": cfg_dict(cfg),
            "weights_bin": f"weights/{cfg.name}.bin",
            "weights_bytes": nbytes,
            "param_spec": [[n, list(s)] for n, s in M.param_spec(cfg)],
            "train_info": {"steps": steps, "train_loss": train_loss,
                           "val_loss": val_loss, "greedy_acc": acc},
            "artifacts": art,
        })
    return entries


def build_scaling(outdir: str, quick: bool) -> List[Dict]:
    entries = []
    variants = SCALING_VARIANTS[:3] if quick else SCALING_VARIANTS
    for cfg in variants:
        cfg = cfg.with_(seq_len=64)
        pstructs = param_structs(cfg)
        P = len(pstructs)
        batch_struct = shape_struct((TRAIN_BATCH, cfg.seq_len), jnp.int32)
        f32_1 = shape_struct((1,), jnp.float32)

        tpath = os.path.join(outdir, "hlo", f"{cfg.name}.train_step.hlo.txt")
        train_desc = lower_to_file(
            make_train_fn(cfg, lr=1e-3),
            pstructs * 3 + [f32_1, batch_struct], tpath)
        epath = os.path.join(outdir, "hlo", f"{cfg.name}.eval_loss.hlo.txt")
        eval_desc = lower_to_file(make_eval_fn(cfg), pstructs + [batch_struct], epath)

        params = M.init_params(cfg, jax.random.PRNGKey(42))
        wpath = os.path.join(outdir, "weights", f"{cfg.name}.init.bin")
        nbytes = write_weights(wpath, cfg, params)
        print(f"[aot]   lowered scaling {cfg.name} ({cfg.param_count():,} params)", flush=True)

        entries.append({
            "name": cfg.name,
            "config": cfg_dict(cfg),
            "init_bin": f"weights/{cfg.name}.init.bin",
            "init_bytes": nbytes,
            "param_spec": [[n, list(s)] for n, s in M.param_spec(cfg)],
            "train_step": train_desc,
            "eval_loss": eval_desc,
            "train_batch": TRAIN_BATCH,
            "n_param_tensors": P,
        })
    return entries


def cfg_dict(cfg: ModelConfig) -> Dict:
    return {
        "name": cfg.name, "d": cfg.d, "h": cfg.h, "g": cfg.g, "k": cfg.k,
        "p": cfg.p, "l": cfg.l, "vocab": cfg.vocab, "ffn_mult": cfg.ffn_mult,
        "m_c_max": cfg.m_c_max, "m_d_max": cfg.m_d_max, "m_max": cfg.m_max,
        "seq_len": cfg.seq_len, "param_count": cfg.param_count(),
        "attention_kind": cfg.attention_kind,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("AOT_STEPS", 1400)))
    ap.add_argument("--quick", action="store_true",
                    help="tiny build for CI: fewer steps, b-buckets {1,4}")
    args = ap.parse_args()

    outdir = os.path.abspath(args.out)
    for sub in ("hlo", "weights"):
        os.makedirs(os.path.join(outdir, sub), exist_ok=True)

    buckets = (1, 4) if args.quick else BATCH_BUCKETS
    steps = 200 if args.quick else args.steps

    t0 = time.time()
    serving = build_serving(outdir, steps, buckets, args.quick)
    scaling = build_scaling(outdir, args.quick)

    manifest = {
        "version": 1,
        "generated_by": "python/compile/aot.py",
        "tokenizer": corpus.tokenizer_table(),
        "batch_buckets": list(buckets),
        "decode_modes": list(DECODE_MODES),
        "serving": serving,
        "scaling": scaling,
        "build_seconds": round(time.time() - t0, 1),
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {outdir}/manifest.json in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
