"""Build-time compile path: JAX model + Pallas kernels -> HLO text artifacts.

Never imported at serving time; the rust binary is self-contained once
``make artifacts`` has run.
"""
