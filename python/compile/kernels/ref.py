"""Pure-jnp oracles for the attention kernels (L1 correctness reference).

Three formulations, all mathematically identical on the single-context
batch-sampling decode step (paper Appendix E.1 proof):

* :func:`decode_attention_ref` — the "naive/fused" semantics: the context
  KV is materialized per batch index (shape ``b g m k``) and a single
  attention runs over the concatenated length. This is the memory-hungry
  baseline (Eq. 1–2 with ``K = K_c ⊕ K_d``).
* :func:`bifurcated_decode_ref` — the paper's Eq. 3–4: two einsums, the
  context one with **no batch axis on K_c/V_c**, joined by concat (logits)
  and sum (values), with one joint softmax.
* :func:`attention_full` — full-sequence multi-group attention used by
  prefill/training (n = m).

Everything here is deliberately straightforward jnp; the Pallas kernels in
``bifurcated.py`` / ``fused.py`` are tested against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _scale(k: int) -> float:
    return 1.0 / (k ** 0.5)


def attention_full(q, kt, vt, length):
    """Full multi-group attention over a whole sequence (prefill/training).

    q:  [B, g, p, n, k]   (n == m during context encoding)
    kt: [B, g, m, k]
    vt: [B, g, m, v]
    length: int32 scalar — valid key positions are j < length.
    Causal: query position i attends to keys j <= i.
    Returns [B, g, p, n, v].
    """
    B, g, p, n, k = q.shape
    m = kt.shape[2]
    logits = jnp.einsum("bgpnk,bgmk->bgpnm", q, kt) * _scale(k)
    i = jax.lax.broadcasted_iota(jnp.int32, (n, m), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (n, m), 1)
    mask = jnp.logical_and(j <= i, j < jnp.asarray(length, jnp.int32))
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bgpnm,bgmv->bgpnv", w, vt)


def _decode_masks(mc, md, m_c_len, d_pos):
    """Masks for the decode step: context keys valid for j < m_c_len,
    decode keys valid for j <= d_pos (the current token attends to itself).
    Shapes broadcastable against [b, g, p, m]."""
    jc = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, mc), 3)
    jd = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, md), 3)
    mask_c = jc < jnp.asarray(m_c_len, jnp.int32)
    mask_d = jd <= jnp.asarray(d_pos, jnp.int32)
    return mask_c, mask_d


def decode_attention_ref(q, kc, vc, kd, vd, m_c_len, d_pos):
    """Fused-semantics oracle (the paper's baseline memory layout).

    q:  [b, g, p, k]          single new token per sequence (n = 1)
    kc: [g, mc, k], vc: [g, mc, v]       shared context KV (single copy)
    kd: [b, g, md, k], vd: [b, g, md, v] per-sequence decode KV
    m_c_len: valid context length; d_pos: index of the current decode step.
    Returns o: [b, g, p, v].

    The context KV is explicitly broadcast to the batch axis and a single
    softmax-attention runs over the concatenated length — i.e. exactly what
    a GEMM over ``K = K_c ⊕ K_d`` computes.
    """
    b, g, p, k = q.shape
    mc = kc.shape[1]
    kc_b = jnp.broadcast_to(kc[None], (b, g, mc, k))
    vc_b = jnp.broadcast_to(vc[None], (b, g, mc, vc.shape[-1]))
    kfull = jnp.concatenate([kc_b, kd], axis=2)
    vfull = jnp.concatenate([vc_b, vd], axis=2)
    return fused_full_ref(q, kfull, vfull, m_c_len, d_pos, mc)


def bifurcated_decode_ref(q, kc, vc, kd, vd, m_c_len, d_pos):
    """The paper's bifurcated formulation (Eq. 3–4), jnp oracle.

    Identical inputs/outputs to :func:`decode_attention_ref`; the context
    einsum carries **no batch axis on K_c** (``bgpk, gmk -> bgpm``) — the
    memory-IO saving — and the value products are joined by summation.
    """
    b, g, p, k = q.shape
    mc = kc.shape[1]
    md = kd.shape[2]
    scale = _scale(k)
    # ⟨q, K_c⟩ : einsum(bgpnk, g m_c k) -> bgpn m_c      (n = 1, folded away)
    logits_c = jnp.einsum("bgpk,gmk->bgpm", q, kc) * scale
    # ⟨q, K_d⟩ : einsum(bgpnk, b g m_d k) -> bgpn m_d
    logits_d = jnp.einsum("bgpk,bgmk->bgpm", q, kd) * scale
    mask_c, mask_d = _decode_masks(mc, md, m_c_len, d_pos)
    logits_c = jnp.where(mask_c, logits_c, NEG_INF)
    logits_d = jnp.where(mask_d, logits_d, NEG_INF)
    # Joint softmax over the concatenated length axis (⊕ on logits).
    joint = jnp.concatenate([logits_c, logits_d], axis=-1)
    w = jax.nn.softmax(joint, axis=-1)
    wc, wd = w[..., :mc], w[..., mc:]
    # ⟨w_c, V_c⟩ + ⟨w_d, V_d⟩ — joined by sum (Eq. 4).
    oc = jnp.einsum("bgpm,gmv->bgpv", wc, vc)
    od = jnp.einsum("bgpm,bgmv->bgpv", wd, vd)
    return oc + od


def fused_full_ref(q, kfull, vfull, m_c_len, d_pos, mc):
    """Oracle for the fused kernel's *layout*: K laid out as
    [b, g, mc + md, k] with context in [0, mc) and decode in [mc, ...).
    """
    b, g, p, k = q.shape
    md = kfull.shape[2] - mc
    logits = jnp.einsum("bgpk,bgmk->bgpm", q, kfull) * _scale(k)
    mask_c, mask_d = _decode_masks(mc, md, m_c_len, d_pos)
    mask = jnp.concatenate(
        [jnp.broadcast_to(mask_c, (b, g, p, mc)), jnp.broadcast_to(mask_d, (b, g, p, md))],
        axis=-1,
    )
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bgpm,bgmv->bgpv", w, vfull)
