"""L1 Pallas kernels (build-time only; lowered into the model HLO).

- ``bifurcated``: the paper's context-aware bifurcated decode attention.
- ``fused``: the baseline decode attention over the replicated KV layout.
- ``ref``: pure-jnp oracles both are verified against.
"""

from . import bifurcated, fused, ref  # noqa: F401
from .bifurcated import bifurcated_decode  # noqa: F401
from .fused import fused_decode  # noqa: F401
