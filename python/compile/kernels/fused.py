"""L1 Pallas kernel: fused (baseline) decode attention.

The paper's baseline (Sec. 4.1): the KV cache is laid out with the context
replicated along the batch axis — ``K = K_c ⊕ K_d`` of shape
``[b, g, mc+md, k]`` — and a single attention GEMM runs over it. The
BlockSpec index map for K/V **depends on the batch index**, so every grid
step re-fetches its own copy of the (identical) context block: memory
traffic ``gk·b·(m_c+m_d)`` (Eq. 5). This is what "naively passing the
whole tensor to the GEMM/BLAS operator" costs, and it is the comparator
for every latency table in the paper.

Layout convention: positions ``[0, mc)`` hold the context (valid where
``j < m_c_len``), positions ``[mc, mc+md)`` hold decode KV (valid where
``j - mc <= d_pos``). The engine materializes the broadcast on the host —
deliberately, because that *is* the baseline's memory behaviour.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fused_kernel(len_ref, pos_ref, q_ref, kf_ref, vf_ref, o_ref, *, scale, mc):
    """Block shapes: q [1,1,p,k], kf/vf [1,1,mt,k], o [1,1,p,k]."""
    q = q_ref[0, 0]            # [p, k]
    kf = kf_ref[0, 0]          # [mt, k] — includes this batch row's context copy
    vf = vf_ref[0, 0]
    p, k = q.shape
    mt = kf.shape[0]

    m_c_len = len_ref[0]
    d_pos = pos_ref[0]

    logits = jnp.dot(q, kf.T, preferred_element_type=jnp.float32) * scale  # [p, mt]
    j = jax.lax.broadcasted_iota(jnp.int32, (p, mt), 1)
    mask = jnp.where(j < mc, j < m_c_len, (j - mc) <= d_pos)
    logits = jnp.where(mask, logits, NEG_INF)

    row_max = jnp.max(logits, axis=-1)
    e = jnp.exp(logits - row_max[:, None])
    denom = jnp.sum(e, axis=-1)
    o_ref[0, 0] = jnp.dot(e, vf, preferred_element_type=jnp.float32) / denom[:, None]


def fused_decode(q, kfull, vfull, m_c_len, d_pos, mc, *, interpret=True):
    """Baseline fused decode attention via Pallas.

    q:     [b, g, p, k]
    kfull: [b, g, mc+md, k]   context replicated per batch row + decode KV
    vfull: [b, g, mc+md, k]
    m_c_len, d_pos: int32[1] scalars; mc: static context capacity.
    Returns o: [b, g, p, k].
    """
    b, g, p, k = q.shape
    mt = kfull.shape[2]
    scale = 1.0 / (k ** 0.5)
    kernel = functools.partial(_fused_kernel, scale=scale, mc=mc)
    return pl.pallas_call(
        kernel,
        grid=(b, g),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, 1, p, k), lambda i, j: (i, j, 0, 0)),
            # K/V maps depend on i: the context copy is re-fetched per
            # batch row — the redundant IO the paper eliminates.
            pl.BlockSpec((1, 1, mt, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, mt, k), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, p, k), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g, p, k), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(m_c_len, jnp.int32).reshape(1),
      jnp.asarray(d_pos, jnp.int32).reshape(1),
      q, kfull, vfull)


def hbm_traffic_bytes(b, g, k, mc, md, dtype_bytes=4):
    """KV bytes moved for the whole decode step under this schedule:
    everything per batch row. Eq. 5."""
    return dtype_bytes * 2 * g * k * b * (mc + md)
