"""L1 Pallas kernel: context-aware bifurcated attention (decode step).

This is the paper's core contribution (Sec. 4) expressed as a Pallas
kernel. The bifurcation is encoded **in the BlockSpec index maps**:

* the K_c / V_c specs map grid point ``(i, j)`` (batch ``i``, group ``j``)
  to block ``(j, 0, 0)`` — *independent of the batch index* ``i`` — so the
  shared context block is fetched HBM→VMEM once per group and reused
  across the whole batch. This is Eq. 3's ``einsum(bgpnk, gm_ck)`` stated
  as a memory schedule;
* the K_d / V_d specs map to ``(i, j, 0, 0)`` — per-batch decode blocks,
  Eq. 3's ``einsum(bgpnk, bgm_dk)``.

Inside the kernel the two logit halves are joined by concatenation, one
joint (numerically-stable) softmax runs over the combined length, and the
two weight–value products are joined by summation (Eq. 4) — so the result
is bit-for-bit the same attention as the unsplit computation, with the
same FLOPs, but with ``gk·(m_c + b·m_d)`` instead of ``gk·b·(m_c+m_d)``
bytes of KV traffic (Eq. 5–6).

TPU adaptation (DESIGN.md §3): on real TPU hardware the context length
axis would additionally be tiled into VMEM-sized blocks with an online
softmax; at the artifact shapes used here (m_c ≤ 96) a single block fits
VMEM trivially, and we run under ``interpret=True`` because the CPU PJRT
plugin cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _bifurcated_kernel(len_ref, pos_ref, q_ref, kc_ref, vc_ref, kd_ref, vd_ref, o_ref, *, scale):
    """One grid step: batch index i, group index j (folded into block maps).

    Block shapes (leading 1s are the blocked grid axes):
      q_ref  [1, 1, p, k]     kc_ref [1, mc, k]   vc_ref [1, mc, k]
      kd_ref [1, 1, md, k]    vd_ref [1, 1, md, k]
      o_ref  [1, 1, p, k]
    """
    q = q_ref[0, 0]            # [p, k]
    kc = kc_ref[0]             # [mc, k]  (shared across batch — loaded once)
    vc = vc_ref[0]
    kd = kd_ref[0, 0]          # [md, k]
    vd = vd_ref[0, 0]
    p, k = q.shape
    mc = kc.shape[0]
    md = kd.shape[0]

    m_c_len = len_ref[0]
    d_pos = pos_ref[0]

    # ⟨q, K_c⟩ and ⟨q, K_d⟩ — same FLOPs as the unsplit GEMM.
    logits_c = jnp.dot(q, kc.T, preferred_element_type=jnp.float32) * scale  # [p, mc]
    logits_d = jnp.dot(q, kd.T, preferred_element_type=jnp.float32) * scale  # [p, md]

    mask_c = jax.lax.broadcasted_iota(jnp.int32, (p, mc), 1) < m_c_len
    mask_d = jax.lax.broadcasted_iota(jnp.int32, (p, md), 1) <= d_pos
    logits_c = jnp.where(mask_c, logits_c, NEG_INF)
    logits_d = jnp.where(mask_d, logits_d, NEG_INF)

    # Joint, numerically-stable softmax across the bifurcation boundary.
    row_max = jnp.maximum(jnp.max(logits_c, axis=-1), jnp.max(logits_d, axis=-1))  # [p]
    ec = jnp.exp(logits_c - row_max[:, None])
    ed = jnp.exp(logits_d - row_max[:, None])
    denom = jnp.sum(ec, axis=-1) + jnp.sum(ed, axis=-1)                            # [p]

    # ⟨w_c, V_c⟩ + ⟨w_d, V_d⟩ — joined by sum (Eq. 4).
    oc = jnp.dot(ec, vc, preferred_element_type=jnp.float32)   # [p, k]
    od = jnp.dot(ed, vd, preferred_element_type=jnp.float32)   # [p, k]
    o_ref[0, 0] = (oc + od) / denom[:, None]


def bifurcated_decode(q, kc, vc, kd, vd, m_c_len, d_pos, *, interpret=True):
    """Bifurcated decode attention via Pallas.

    q:  [b, g, p, k]                         (single query token, n = 1)
    kc: [g, mc, k], vc: [g, mc, k]           shared context KV — one copy
    kd: [b, g, md, k], vd: [b, g, md, k]     per-sequence decode KV
    m_c_len: int32[1] valid context length; d_pos: int32[1] decode index.
    Returns o: [b, g, p, k].
    """
    b, g, p, k = q.shape
    mc = kc.shape[1]
    md = kd.shape[2]
    scale = 1.0 / (k ** 0.5)
    kernel = functools.partial(_bifurcated_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, g),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),            # m_c_len (scalar)
            pl.BlockSpec(memory_space=pl.ANY),            # d_pos   (scalar)
            pl.BlockSpec((1, 1, p, k), lambda i, j: (i, j, 0, 0)),
            # Context KV block maps ignore the batch grid index i: the
            # block is the same for every i — bifurcation as a schedule.
            pl.BlockSpec((1, mc, k), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, mc, k), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, 1, md, k), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, md, k), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, p, k), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g, p, k), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(m_c_len, jnp.int32).reshape(1),
      jnp.asarray(d_pos, jnp.int32).reshape(1),
      q, kc, vc, kd, vd)


def vmem_footprint_bytes(b, g, p, k, mc, md, dtype_bytes=4):
    """Static VMEM working-set estimate for one grid step of the kernel
    (used by the §Perf analysis; interpret-mode wallclock is not a TPU
    proxy, the block structure is what we optimize)."""
    q_blk = p * k
    kv_c = 2 * mc * k
    kv_d = 2 * md * k
    logits = p * (mc + md)
    out = p * k
    return dtype_bytes * (q_blk + kv_c + kv_d + logits + out)


def hbm_traffic_bytes(b, g, k, mc, md, dtype_bytes=4):
    """KV bytes moved HBM->VMEM for the whole decode step under this
    schedule: context once (per group), decode per batch. Eq. 6."""
    return dtype_bytes * 2 * g * k * (mc + b * md)
