"""Synthetic arithmetic corpus + tokenizer.

The paper evaluates single-context batch sampling on code-generation tasks
(MBPP/MBXP) with real 16B models; offline we substitute a *checkable
synthetic language* — addition expressions ``a+b=c;`` — that a pico-scale
model can genuinely learn at artifact-build time. The grammar is shared
verbatim with the rust eval harness (``rust/src/evalharness``): a task is a
prompt ``a+b=`` whose unique correct completion is ``c;``, so pass@n /
pass@top3 (Fig. 8/10) are computable by string checking exactly as MBPP
checks execution.

Tokenizer: fixed character vocabulary, id-stable across python and rust
(the table is exported in artifacts/manifest.json).
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

PAD = 0
BOS = 1
# characters, ids 2..14
_CHARS = "0123456789+=;"
CHAR_TO_ID = {c: i + 2 for i, c in enumerate(_CHARS)}
ID_TO_CHAR = {i + 2: c for i, c in enumerate(_CHARS)}
VOCAB_SIZE = 16  # 2 specials + 13 chars + 1 spare (keeps vocab a power of 2)

SEMI = CHAR_TO_ID[";"]
EQ = CHAR_TO_ID["="]

# Operand range: kept small so a ~1M-param model trained for a few thousand
# steps reaches a useful-but-imperfect per-sample accuracy — the regime in
# which pass@n actually improves with n (paper Fig. 8).
MAX_OPERAND = 19


def encode(s: str) -> List[int]:
    return [CHAR_TO_ID[c] for c in s]


def decode_ids(ids) -> str:
    return "".join(ID_TO_CHAR.get(int(i), "") for i in ids)


def expression(a: int, b: int) -> str:
    return f"{a}+{b}={a + b};"


def sample_expression(rng: np.random.Generator) -> str:
    a = int(rng.integers(0, MAX_OPERAND + 1))
    b = int(rng.integers(0, MAX_OPERAND + 1))
    return expression(a, b)


def token_stream(rng: np.random.Generator, n_tokens: int) -> np.ndarray:
    """An endless concatenation of random expressions, truncated to n_tokens."""
    out: List[int] = []
    while len(out) < n_tokens:
        out.extend(encode(sample_expression(rng)))
    return np.asarray(out[:n_tokens], dtype=np.int32)


def training_batch(rng: np.random.Generator, batch: int, seq_len: int) -> np.ndarray:
    """[batch, seq_len] int32 token windows, each starting with BOS."""
    rows = []
    for _ in range(batch):
        row = np.concatenate([[BOS], token_stream(rng, seq_len - 1)])
        rows.append(row)
    return np.stack(rows).astype(np.int32)


def make_prompt(rng: np.random.Generator, n_shots: int, a: int, b: int) -> str:
    """A shared-prefix prompt: ``n_shots`` solved examples then ``a+b=``.

    This is the paper's single-context scenario: the prompt (context) is
    long relative to the completion, so K_c dominates the KV cache.
    """
    shots = "".join(sample_expression(rng) for _ in range(n_shots))
    return shots + f"{a}+{b}="


def prompt_tokens(prompt: str, m_c_max: int) -> Tuple[np.ndarray, int]:
    """BOS + encoded prompt, right-padded with PAD to m_c_max. Returns
    (tokens[1, m_c_max], true_length)."""
    ids = [BOS] + encode(prompt)
    if len(ids) > m_c_max:
        raise ValueError(f"prompt of {len(ids)} tokens exceeds m_c_max={m_c_max}")
    length = len(ids)
    padded = ids + [PAD] * (m_c_max - length)
    return np.asarray([padded], dtype=np.int32), length


def check_completion(a: int, b: int, completion: str) -> bool:
    """A completion is correct iff it starts with ``{a+b};``."""
    want = f"{a + b};"
    return completion.startswith(want)


def tokenizer_table() -> dict:
    """Exported to the manifest so rust shares the exact vocabulary."""
    return {
        "pad": PAD,
        "bos": BOS,
        "semicolon": SEMI,
        "equals": EQ,
        "vocab_size": VOCAB_SIZE,
        "chars": {c: i for c, i in CHAR_TO_ID.items()},
        "max_operand": MAX_OPERAND,
    }
