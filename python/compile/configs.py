"""Model configurations for the bifurcated-attention reproduction.

Two families:

* the ``pico`` *serving* family — three capability-comparable variants of a
  small LM (multi-head ``g=h``, multi-group ``1<g<h``, multi-query ``g=1``)
  that are trained at artifact-build time on the synthetic arithmetic corpus
  and then AOT-lowered (prefill + bucketed decode steps) for the rust
  serving engine;

* the *scaling-law* family (paper Fig. 3 / Fig. 9) — a grid of sizes x
  attention types whose ``train_step`` / ``eval_loss`` entry points are
  AOT-lowered with parameters as explicit inputs/outputs so the rust
  coordinator can drive the training runs itself.

All shapes here are static: the AOT interchange is HLO text, which has no
dynamic dimensions, so batch sizes are bucketed and sequence capacities are
fixed per artifact (mirroring how production engines pre-compile shape
buckets).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of one multi-group transformer LM.

    Notation follows the paper (Sec. 3.1): ``d`` hidden dim, ``h`` query
    heads, ``g`` attention groups (``g=h`` multi-head, ``g=1`` multi-query),
    ``k = d/h`` head dim, ``l`` layers, ``m_c``/``m_d`` context/decode
    KV-cache capacities.
    """

    name: str
    d: int                      # hidden dimension
    h: int                      # number of query heads
    g: int                      # number of attention groups (1 <= g <= h)
    l: int                      # number of layers
    vocab: int                  # vocabulary size
    ffn_mult: int = 4           # feed-forward fanout (paper's 2d ablation uses 2)
    m_c_max: int = 96           # context KV capacity (prefill length bucket)
    m_d_max: int = 32           # decode KV capacity (max generated tokens)
    seq_len: int = 64           # training sequence length
    tie_embeddings: bool = False

    @property
    def k(self) -> int:
        """Head dimension."""
        assert self.d % self.h == 0, f"{self.name}: d={self.d} not divisible by h={self.h}"
        return self.d // self.h

    @property
    def p(self) -> int:
        """Attention group size h/g (queries per KV group)."""
        assert self.h % self.g == 0, f"{self.name}: h={self.h} not divisible by g={self.g}"
        return self.h // self.g

    @property
    def m_max(self) -> int:
        """Positional-table capacity."""
        return max(self.m_c_max + self.m_d_max, self.seq_len)

    @property
    def attention_kind(self) -> str:
        if self.g == 1:
            return "multi_query"
        if self.g == self.h:
            return "multi_head"
        return "multi_group"

    def param_count(self) -> int:
        """Exact parameter count (matches model.init_params)."""
        d, k, v = self.d, self.k, self.vocab
        per_layer = (
            2 * d                          # ln1 scale/bias
            + d * self.h * k               # wq
            + 2 * d * self.g * k           # wk, wv
            + self.h * k * d               # wo
            + 2 * d                        # ln2 scale/bias
            + d * self.ffn_mult * d + self.ffn_mult * d   # w1, b1
            + self.ffn_mult * d * d + d    # w2, b2
        )
        head = 0 if self.tie_embeddings else d * v
        return v * d + self.m_max * d + self.l * per_layer + 2 * d + head

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Serving family ("pico"): d=64, h=8 (single-core CPU build budget) — three capability-comparable variants.
# The MQ/MG variants get extra layers, mirroring the paper's size
# compensation (Sec. 5.1: MQ needs ~1.1x parameters to match MH).
# ---------------------------------------------------------------------------

VOCAB = 16  # set by the corpus tokenizer; asserted in aot.py

PICO_MH = ModelConfig(name="pico-mh", d=64, h=8, g=8, l=3, vocab=VOCAB)
PICO_MG = ModelConfig(name="pico-mg", d=64, h=8, g=2, l=3, vocab=VOCAB)
PICO_MQ = ModelConfig(name="pico-mq", d=64, h=8, g=1, l=3, vocab=VOCAB)

SERVING_VARIANTS: List[ModelConfig] = [PICO_MH, PICO_MG, PICO_MQ]

# Batch-size buckets compiled for the decode step. The rust engine pads a
# request's sample count up to the next bucket.
BATCH_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)

# Decode attention implementations lowered per bucket.
DECODE_MODES: Tuple[str, ...] = ("bifurcated", "fused")


# ---------------------------------------------------------------------------
# Scaling-law family (Fig. 3 / Fig. 9): sizes x {MH, MG, MQ} + 2d-FFN
# ablation. Parameters are explicit I/O; training is driven from rust.
# ---------------------------------------------------------------------------

def _scaling_grid() -> List[ModelConfig]:
    base = [
        # (tag, d, h, l)
        ("s0", 32, 4, 2),
        ("s1", 48, 4, 3),
        ("s2", 64, 8, 4),
        ("s3", 80, 8, 4),
    ]
    out: List[ModelConfig] = []
    for tag, d, h, l in base:
        for kind, g in (("mh", h), ("mg", 2), ("mq", 1)):
            out.append(
                ModelConfig(
                    name=f"scale-{tag}-{kind}", d=d, h=h, g=g, l=l,
                    vocab=VOCAB, m_c_max=0, m_d_max=0,
                )
            )
    # 2d-FFN ablation (paper Appendix C.4 / Fig. 9): multi-group with the
    # feed-forward fanout halved, for two sizes.
    for tag, d, h, l in [("s1", 48, 4, 3), ("s2", 64, 8, 4)]:
        out.append(
            ModelConfig(
                name=f"scale-{tag}-mg2d", d=d, h=h, g=2, l=l,
                vocab=VOCAB, ffn_mult=2, m_c_max=0, m_d_max=0,
            )
        )
    return out


SCALING_VARIANTS: List[ModelConfig] = _scaling_grid()

TRAIN_BATCH = 32   # training batch for the scaling family (rust-driven)
PICO_TRAIN_BATCH = 32


def find_config(name: str) -> ModelConfig:
    for c in SERVING_VARIANTS + SCALING_VARIANTS:
        if c.name == name:
            return c
    raise KeyError(f"unknown model config: {name}")
