"""L2 — the multi-group transformer LM (JAX, build-time only).

A GPT-style decoder with *generalized multi-group attention* (paper
Sec. 3.3): ``g`` key/value groups shared across ``h`` query heads, so
``g=h`` is multi-head, ``g=1`` multi-query, in-between multi-group. The
attention layouts all use the paper's ``bgpnk`` einsum convention.

Entry points (all pure functions over an ordered flat param list, so they
AOT-lower to HLO with a stable signature the rust runtime can drive):

* :func:`prefill`       — context encoding: full causal attention over the
                          (padded) prompt; emits the shared K_c/V_c cache
                          and the next-token logits.
* :func:`decode_step`   — one incremental-decoding step; the attention
                          hot-spot is the L1 Pallas kernel, either
                          ``bifurcated`` (Eq. 3–4) or ``fused`` (baseline).
* :func:`train_step`    — Adam training step with params/opt-state as
                          explicit I/O (the rust scaling-law driver loops
                          over this HLO).
* :func:`eval_loss`     — held-out loss (scaling-law measurements).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import bifurcated_decode, fused_decode
from .kernels.ref import attention_full

Params = Dict[str, jax.Array]

# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the flattening order used by every
    AOT entry point and recorded in the artifact manifest."""
    d, k, ff = cfg.d, cfg.k, cfg.ffn_mult * cfg.d
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("emb", (cfg.vocab, d)),
        ("pos", (cfg.m_max, d)),
    ]
    for i in range(cfg.l):
        spec += [
            (f"L{i}.ln1_s", (d,)),
            (f"L{i}.ln1_b", (d,)),
            (f"L{i}.wq", (d, cfg.h * k)),
            (f"L{i}.wk", (d, cfg.g * k)),
            (f"L{i}.wv", (d, cfg.g * k)),
            (f"L{i}.wo", (cfg.h * k, d)),
            (f"L{i}.ln2_s", (d,)),
            (f"L{i}.ln2_b", (d,)),
            (f"L{i}.w1", (d, ff)),
            (f"L{i}.b1", (ff,)),
            (f"L{i}.w2", (ff, d)),
            (f"L{i}.b2", (d,)),
        ]
    spec += [("lnf_s", (d,)), ("lnf_b", (d,)), ("head", (d, cfg.vocab))]
    return spec


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """GPT-2-style init: normal(0, 0.02) matrices, residual projections
    scaled by 1/sqrt(2l) (Shoeybi et al.), zero biases, unit LN scales."""
    spec = param_spec(cfg)
    keys = jax.random.split(key, len(spec))
    params: Params = {}
    resid_scale = 1.0 / np.sqrt(2.0 * cfg.l)
    for (name, shape), kk in zip(spec, keys):
        base = name.split(".")[-1]
        if base in ("ln1_s", "ln2_s", "lnf_s"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif base in ("ln1_b", "ln2_b", "lnf_b", "b1", "b2"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            std = 0.02 * (resid_scale if base in ("wo", "w2") else 1.0)
            params[name] = jax.random.normal(kk, shape, jnp.float32) * std
    return params


def flatten_params(cfg: ModelConfig, params: Params) -> List[jax.Array]:
    return [params[name] for name, _ in param_spec(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> Params:
    spec = param_spec(cfg)
    assert len(flat) == len(spec), f"{len(flat)} arrays vs spec {len(spec)}"
    return {name: a for (name, _), a in zip(spec, flat)}


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------


def _ln(x, s, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * s + b


def _split_heads_q(q, cfg: ModelConfig):
    """[..., h*k] -> [..., g, p, k]"""
    new = q.shape[:-1] + (cfg.g, cfg.p, cfg.k)
    return q.reshape(new)


def _block_full(x, lp: Dict[str, jax.Array], cfg: ModelConfig, length):
    """One transformer block over a full sequence. x: [B, S, d]."""
    B, S, d = x.shape
    h1 = _ln(x, lp["ln1_s"], lp["ln1_b"])
    q = _split_heads_q(h1 @ lp["wq"], cfg)                  # [B,S,g,p,k]
    q = q.transpose(0, 2, 3, 1, 4)                          # [B,g,p,S,k]
    kt = (h1 @ lp["wk"]).reshape(B, S, cfg.g, cfg.k).transpose(0, 2, 1, 3)  # [B,g,S,k]
    vt = (h1 @ lp["wv"]).reshape(B, S, cfg.g, cfg.k).transpose(0, 2, 1, 3)
    o = attention_full(q, kt, vt, length)                   # [B,g,p,S,k]
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, S, cfg.h * cfg.k)
    x = x + o @ lp["wo"]
    h2 = _ln(x, lp["ln2_s"], lp["ln2_b"])
    x = x + (jax.nn.gelu(h2 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"])
    return x, kt, vt


def _layer_params(params: Params, i: int) -> Dict[str, jax.Array]:
    pre = f"L{i}."
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


def forward_full(params: Params, cfg: ModelConfig, tokens, length):
    """Full forward: tokens [B, S] int32 -> logits [B, S, vocab].
    Also returns per-layer K/V stacks [l, B, g, S, k] (the prefill cache)."""
    B, S = tokens.shape
    x = params["emb"][tokens] + params["pos"][:S][None]
    ks, vs = [], []
    for i in range(cfg.l):
        x, kt, vt = _block_full(x, _layer_params(params, i), cfg, length)
        ks.append(kt)
        vs.append(vt)
    x = _ln(x, params["lnf_s"], params["lnf_b"])
    logits = x @ params["head"]
    return logits, jnp.stack(ks), jnp.stack(vs)


# --------------------------------------------------------------------------
# Prefill (context encoding)
# --------------------------------------------------------------------------


def prefill(params: Params, cfg: ModelConfig, tokens, length):
    """Context encoding for a single prompt.

    tokens: [1, m_c_max] int32 (right-padded); length: int32 scalar.
    Returns (logits_last [1, vocab], kc [l, g, m_c_max, k], vc [...]).
    """
    logits, ks, vs = forward_full(params, cfg, tokens, length)
    # Next-token logits live at the last *valid* position.
    last = jax.lax.dynamic_slice_in_dim(
        logits, jnp.asarray(length, jnp.int32) - 1, 1, axis=1
    )[:, 0]                                   # [1, vocab]
    kc = ks[:, 0]                             # [l, g, m_c_max, k]
    vc = vs[:, 0]
    return last, kc, vc


# --------------------------------------------------------------------------
# Incremental decode step
# --------------------------------------------------------------------------


def decode_step(params: Params, cfg: ModelConfig, mode: str, tokens, d_pos,
                m_c_len, kc, vc, kd, vd, *, interpret=True):
    """One incremental-decoding step over a batch of b samplers sharing one
    context (single-context batch sampling, paper Fig. 1 right).

    tokens: [b] int32 — the tokens sampled at the previous step.
    d_pos:  int32 scalar — how many decode tokens precede this one.
    m_c_len: int32 scalar — valid context length.
    mode == "bifurcated": kc/vc are the *shared* caches [l, g, mc, k].
    mode == "fused":      kc/vc are *replicated* caches [l, b, g, mc, k]
                          (the engine materializes the broadcast — that is
                          the baseline under measurement).
    kd/vd: [l, b, g, md, k] decode caches (functional update returned).

    Returns (logits [b, vocab], kd', vd').
    """
    assert mode in ("bifurcated", "fused"), mode
    b = tokens.shape[0]
    pos_idx = jnp.asarray(m_c_len, jnp.int32) + jnp.asarray(d_pos, jnp.int32)
    pos_row = jax.lax.dynamic_slice_in_dim(params["pos"], pos_idx, 1, axis=0)
    x = params["emb"][tokens] + pos_row                     # [b, d]

    new_kd, new_vd = [], []
    for i in range(cfg.l):
        lp = _layer_params(params, i)
        h1 = _ln(x, lp["ln1_s"], lp["ln1_b"])
        q = _split_heads_q(h1 @ lp["wq"], cfg)              # [b, g, p, k]
        knew = (h1 @ lp["wk"]).reshape(b, cfg.g, 1, cfg.k)  # [b, g, 1, k]
        vnew = (h1 @ lp["wv"]).reshape(b, cfg.g, 1, cfg.k)
        kd_i = jax.lax.dynamic_update_slice_in_dim(kd[i], knew, d_pos, axis=2)
        vd_i = jax.lax.dynamic_update_slice_in_dim(vd[i], vnew, d_pos, axis=2)
        new_kd.append(kd_i)
        new_vd.append(vd_i)

        if mode == "bifurcated":
            o = bifurcated_decode(q, kc[i], vc[i], kd_i, vd_i, m_c_len, d_pos,
                                  interpret=interpret)
        else:
            # Replicated layout [b, g, mc+md, k]: context copy then decode.
            kfull = jnp.concatenate([kc[i], kd_i], axis=2)
            vfull = jnp.concatenate([vc[i], vd_i], axis=2)
            o = fused_decode(q, kfull, vfull, m_c_len, d_pos, cfg.m_c_max,
                             interpret=interpret)
        o = o.reshape(b, cfg.h * cfg.k)
        x = x + o @ lp["wo"]
        h2 = _ln(x, lp["ln2_s"], lp["ln2_b"])
        x = x + (jax.nn.gelu(h2 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"])

    x = _ln(x, params["lnf_s"], params["lnf_b"])
    logits = x @ params["head"]                             # [b, vocab]
    return logits, jnp.stack(new_kd), jnp.stack(new_vd)


# --------------------------------------------------------------------------
# Training (scaling-law study, rust-driven)
# --------------------------------------------------------------------------


def loss_fn(params: Params, cfg: ModelConfig, batch):
    """Next-token cross-entropy. batch: [B, S] int32."""
    logits, _, _ = forward_full(params, cfg, batch, batch.shape[1])
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = batch[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8
GRAD_CLIP = 1.0


def train_step(params: Params, m: Params, v: Params, step, batch, cfg: ModelConfig,
               lr: float = 1e-3):
    """One Adam step (beta2 = 0.95 per the paper's setup, global-norm clip
    1.0; weight decay omitted at these scales).

    ``step`` is a float32 scalar (1-based) used for bias correction —
    explicit I/O so the rust driver owns the loop.
    Returns (params', m', v', loss).
    """
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    scale = jnp.minimum(1.0, GRAD_CLIP / (gnorm + 1e-12))
    new_p, new_m, new_v = {}, {}, {}
    bc1 = 1.0 - ADAM_B1 ** step
    bc2 = 1.0 - ADAM_B2 ** step
    for name, g in grads.items():
        g = g * scale
        m_ = ADAM_B1 * m[name] + (1 - ADAM_B1) * g
        v_ = ADAM_B2 * v[name] + (1 - ADAM_B2) * g * g
        update = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + ADAM_EPS)
        new_p[name] = params[name] - lr * update
        new_m[name] = m_
        new_v[name] = v_
    return new_p, new_m, new_v, loss


def eval_loss(params: Params, cfg: ModelConfig, batch):
    return loss_fn(params, cfg, batch)


def zeros_like_params(cfg: ModelConfig) -> Params:
    return {name: jnp.zeros(shape, jnp.float32) for name, shape in param_spec(cfg)}


# --------------------------------------------------------------------------
# Build-time convenience: jitted pico training (python-side, for the
# serving family whose weights ship in the artifacts).
# --------------------------------------------------------------------------


def make_jitted_train(cfg: ModelConfig, lr: float = 1e-3):
    @jax.jit
    def step_fn(params, m, v, step, batch):
        return train_step(params, m, v, step, batch, cfg, lr=lr)

    return step_fn
