"""AOT lowering tests: HLO text is produced, parseable-looking, and the
manifest descriptors carry the shapes the rust runtime relies on."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.configs import ModelConfig
from compile import model as M

TINY = ModelConfig(name="tiny-aot", d=32, h=4, g=2, l=1, vocab=16,
                   m_c_max=16, m_d_max=4, seq_len=16)


def test_to_hlo_text_simple():
    f = lambda x, y: (jnp.matmul(x, y) + 1.0,)
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    txt = aot.to_hlo_text(jax.jit(f).lower(spec, spec))
    assert "HloModule" in txt
    assert "f32[2,2]" in txt


def test_lower_decode_to_file(tmp_path):
    path = str(tmp_path / "dec.hlo.txt")
    pstructs = aot.param_structs(TINY)
    i32_1 = aot.shape_struct((1,), jnp.int32)
    b = 2
    example = pstructs + [
        aot.shape_struct((b,), jnp.int32), i32_1, i32_1,
        aot.shape_struct((TINY.l, TINY.g, TINY.m_c_max, TINY.k)),
        aot.shape_struct((TINY.l, TINY.g, TINY.m_c_max, TINY.k)),
        aot.shape_struct((TINY.l, b, TINY.g, TINY.m_d_max, TINY.k)),
        aot.shape_struct((TINY.l, b, TINY.g, TINY.m_d_max, TINY.k)),
    ]
    desc = aot.lower_to_file(aot.make_decode_fn(TINY, "bifurcated"), example, path)
    assert os.path.exists(path)
    txt = open(path).read()
    assert "HloModule" in txt
    assert desc["bytes"] == len(txt)
    assert len(desc["args"]) == len(example)
    # token arg shape recorded correctly
    assert desc["args"][len(pstructs)]["shape"] == [b]


def test_weights_roundtrip(tmp_path):
    params = M.init_params(TINY, jax.random.PRNGKey(0))
    path = str(tmp_path / "w.bin")
    n = aot.write_weights(path, TINY, params)
    assert n == 4 * TINY.param_count()
    raw = np.fromfile(path, dtype="<f4")
    # reconstruct and compare tensor-by-tensor
    off = 0
    for name, shape in M.param_spec(TINY):
        size = int(np.prod(shape))
        got = raw[off:off + size].reshape(shape)
        np.testing.assert_array_equal(got, np.asarray(params[name]))
        off += size
    assert off == raw.size


def test_cfg_dict_fields():
    d = aot.cfg_dict(TINY)
    for key in ("d", "h", "g", "k", "p", "l", "vocab", "m_c_max", "m_d_max",
                "param_count", "attention_kind"):
        assert key in d
    assert d["attention_kind"] == "multi_group"


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")),
    reason="artifacts not built")
def test_built_manifest_is_consistent():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man = json.load(open(os.path.join(root, "manifest.json")))
    assert man["version"] == 1
    assert man["tokenizer"]["vocab_size"] == 16
    for entry in man["serving"]:
        wpath = os.path.join(root, entry["weights_bin"])
        assert os.path.getsize(wpath) == entry["weights_bytes"]
        total = sum(int(np.prod(s)) for _, s in entry["param_spec"])
        assert entry["weights_bytes"] == 4 * total
        for mode, byb in entry["artifacts"]["decode"].items():
            for b, desc in byb.items():
                assert os.path.exists(os.path.join(root, desc["file"])), desc["file"]
    for entry in man["scaling"]:
        assert os.path.exists(os.path.join(root, entry["train_step"]["file"]))
        assert os.path.exists(os.path.join(root, entry["eval_loss"]["file"]))
