"""Tokenizer/grammar tests — the contract shared with the rust eval harness."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import corpus


def test_roundtrip():
    s = "12+7=19;"
    assert corpus.decode_ids(corpus.encode(s)) == s


def test_vocab_ids_stable():
    # The rust side hard-codes this table via the manifest; pin it here too.
    assert corpus.PAD == 0 and corpus.BOS == 1
    assert corpus.CHAR_TO_ID["0"] == 2
    assert corpus.CHAR_TO_ID["9"] == 11
    assert corpus.CHAR_TO_ID["+"] == 12
    assert corpus.CHAR_TO_ID["="] == 13
    assert corpus.CHAR_TO_ID[";"] == 14
    assert corpus.VOCAB_SIZE == 16


@given(st.integers(0, corpus.MAX_OPERAND), st.integers(0, corpus.MAX_OPERAND))
def test_expression_checkable(a, b):
    expr = corpus.expression(a, b)
    prompt, completion = expr.split("=")
    assert corpus.check_completion(a, b, completion)
    assert not corpus.check_completion(a, b, f"{a + b + 1};")


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20)
def test_stream_tokens_valid(seed):
    rng = np.random.default_rng(seed)
    toks = corpus.token_stream(rng, 100)
    assert toks.shape == (100,)
    assert toks.min() >= 2 and toks.max() < corpus.VOCAB_SIZE


def test_training_batch_shape_and_bos():
    rng = np.random.default_rng(0)
    b = corpus.training_batch(rng, 5, 32)
    assert b.shape == (5, 32)
    assert (b[:, 0] == corpus.BOS).all()


def test_training_batch_deterministic_by_seed():
    a = corpus.training_batch(np.random.default_rng(42), 3, 16)
    b = corpus.training_batch(np.random.default_rng(42), 3, 16)
    np.testing.assert_array_equal(a, b)


def test_prompt_tokens_padding():
    toks, ln = corpus.prompt_tokens("1+2=", 24)
    assert toks.shape == (1, 24)
    assert ln == 5  # BOS + 4 chars
    assert (toks[0, ln:] == corpus.PAD).all()
    assert toks[0, 0] == corpus.BOS


def test_prompt_too_long_raises():
    import pytest
    with pytest.raises(ValueError):
        corpus.prompt_tokens("1+2=" * 50, 24)


def test_make_prompt_contains_question():
    rng = np.random.default_rng(1)
    p = corpus.make_prompt(rng, n_shots=3, a=7, b=8)
    assert p.endswith("7+8=")
    assert p.count(";") == 3
