"""Training-step tests: descent, Adam state, and flat AOT wrapper parity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus
from compile import model as M
from compile.aot import make_decode_fn, make_eval_fn, make_train_fn, param_structs
from compile.configs import ModelConfig

TINY = ModelConfig(name="tiny-train", d=32, h=4, g=2, l=2, vocab=16,
                   m_c_max=16, m_d_max=8, seq_len=32)


def _fresh():
    params = M.init_params(TINY, jax.random.PRNGKey(0))
    return params, M.zeros_like_params(TINY), M.zeros_like_params(TINY)


def test_loss_decreases():
    params, m, v = _fresh()
    rng = np.random.default_rng(0)
    step_fn = M.make_jitted_train(TINY, lr=3e-3)
    losses = []
    for i in range(1, 31):
        batch = corpus.training_batch(rng, 8, TINY.seq_len)
        params, m, v, loss = step_fn(params, m, v, jnp.float32(i), batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.85, losses[::10]
    assert all(np.isfinite(losses))


def test_adam_state_updates():
    params, m, v = _fresh()
    rng = np.random.default_rng(1)
    batch = corpus.training_batch(rng, 4, TINY.seq_len)
    p2, m2, v2, _ = M.train_step(params, m, v, jnp.float32(1), jnp.asarray(batch), TINY)
    # first step: m = (1-b1) g, v = (1-b2) g^2 — nonzero wherever grads are
    assert float(jnp.abs(m2["head"]).sum()) > 0
    assert float(v2["head"].min()) >= 0
    assert float(jnp.abs(p2["head"] - params["head"]).max()) > 0


def test_flat_train_wrapper_matches_dict_version():
    params, m, v = _fresh()
    rng = np.random.default_rng(2)
    batch = jnp.asarray(corpus.training_batch(rng, 4, TINY.seq_len))
    fn = make_train_fn(TINY, lr=1e-3)
    flat_in = (
        M.flatten_params(TINY, params) + M.flatten_params(TINY, m)
        + M.flatten_params(TINY, v) + [jnp.ones((1,), jnp.float32), batch]
    )
    out = fn(*flat_in)
    P = len(M.param_spec(TINY))
    assert len(out) == 3 * P + 1
    p2, m2, v2, loss = M.train_step(params, m, v, jnp.float32(1), batch, TINY, lr=1e-3)
    want = M.flatten_params(TINY, p2)
    for a, b in zip(out[:P], want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out[-1])[0], float(loss), atol=1e-6)


def test_flat_eval_wrapper():
    params, _, _ = _fresh()
    rng = np.random.default_rng(3)
    batch = jnp.asarray(corpus.training_batch(rng, 4, TINY.seq_len))
    fn = make_eval_fn(TINY)
    out = fn(*(M.flatten_params(TINY, params) + [batch]))
    np.testing.assert_allclose(
        np.asarray(out[0])[0], float(M.eval_loss(params, TINY, batch)), atol=1e-6
    )


def test_flat_decode_wrapper_matches_dict_version():
    params, _, _ = _fresh()
    cfg = TINY
    b = 2
    key = jax.random.PRNGKey(4)
    kc = jax.random.normal(key, (cfg.l, cfg.g, cfg.m_c_max, cfg.k)) * 0.3
    vc = jax.random.normal(key, (cfg.l, cfg.g, cfg.m_c_max, cfg.k)) * 0.3
    kd = jnp.zeros((cfg.l, b, cfg.g, cfg.m_d_max, cfg.k))
    vd = jnp.zeros_like(kd)
    toks = jnp.array([2, 3], jnp.int32)
    fn = make_decode_fn(cfg, "bifurcated")
    out = fn(*(M.flatten_params(cfg, params)
               + [toks, jnp.array([1], jnp.int32), jnp.array([9], jnp.int32),
                  kc, vc, kd, vd]))
    want = M.decode_step(params, cfg, "bifurcated", toks, 1, 9, kc, vc, kd, vd)
    for a, b_ in zip(out, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


def test_param_structs_match_spec():
    structs = param_structs(TINY)
    spec = M.param_spec(TINY)
    assert len(structs) == len(spec)
    for st_, (_, shape) in zip(structs, spec):
        assert st_.shape == tuple(shape)
