"""L2 model tests: shapes, bifurcated==fused through the full decode step,
and prefill→incremental-decode consistency against the full forward pass.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus
from compile import model as M
from compile.configs import ModelConfig

ATOL = 1e-4

TINY = ModelConfig(name="tiny-mg", d=32, h=4, g=2, l=2, vocab=16,
                   m_c_max=24, m_d_max=8)
TINY_MQ = TINY.with_(name="tiny-mq", g=1)
TINY_MH = TINY.with_(name="tiny-mh", g=4)


@pytest.fixture(scope="module")
def params():
    return {c.name: M.init_params(c, jax.random.PRNGKey(0))
            for c in (TINY, TINY_MQ, TINY_MH)}


def test_param_spec_matches_init(params):
    for cfg in (TINY, TINY_MQ, TINY_MH):
        spec = M.param_spec(cfg)
        p = params[cfg.name]
        assert set(p) == {n for n, _ in spec}
        for n, s in spec:
            assert p[n].shape == tuple(s), (cfg.name, n)
        total = sum(int(np.prod(s)) for _, s in spec)
        assert total == cfg.param_count()


def test_flatten_roundtrip(params):
    p = params[TINY.name]
    flat = M.flatten_params(TINY, p)
    back = M.unflatten_params(TINY, flat)
    for n in p:
        np.testing.assert_array_equal(np.asarray(p[n]), np.asarray(back[n]))


def test_forward_shapes(params):
    toks = jnp.zeros((3, 16), jnp.int32)
    logits, ks, vs = M.forward_full(params[TINY.name], TINY, toks, 16)
    assert logits.shape == (3, 16, TINY.vocab)
    assert ks.shape == (TINY.l, 3, TINY.g, 16, TINY.k)
    assert vs.shape == ks.shape


def test_prefill_shapes(params):
    toks, ln = corpus.prompt_tokens("1+2=", TINY.m_c_max)
    logits, kc, vc = M.prefill(params[TINY.name], TINY, jnp.asarray(toks), ln)
    assert logits.shape == (1, TINY.vocab)
    assert kc.shape == (TINY.l, TINY.g, TINY.m_c_max, TINY.k)


@pytest.mark.parametrize("cfgname", ["tiny-mg", "tiny-mq", "tiny-mh"])
def test_decode_bifurcated_equals_fused(params, cfgname):
    cfg = {c.name: c for c in (TINY, TINY_MQ, TINY_MH)}[cfgname]
    p = params[cfgname]
    b = 4
    key = jax.random.PRNGKey(1)
    kc = jax.random.normal(key, (cfg.l, cfg.g, cfg.m_c_max, cfg.k)) * 0.3
    vc = jax.random.normal(key, (cfg.l, cfg.g, cfg.m_c_max, cfg.k)) * 0.3
    kd = jnp.zeros((cfg.l, b, cfg.g, cfg.m_d_max, cfg.k))
    vd = jnp.zeros_like(kd)
    toks = jnp.array([2, 3, 4, 5], jnp.int32)
    lg_b, kd_b, vd_b = M.decode_step(p, cfg, "bifurcated", toks, 0, 20, kc, vc, kd, vd)
    kcb = jnp.broadcast_to(kc[:, None], (cfg.l, b) + kc.shape[1:])
    vcb = jnp.broadcast_to(vc[:, None], (cfg.l, b) + vc.shape[1:])
    lg_f, kd_f, vd_f = M.decode_step(p, cfg, "fused", toks, 0, 20, kcb, vcb, kd, vd)
    np.testing.assert_allclose(np.asarray(lg_b), np.asarray(lg_f), atol=ATOL)
    np.testing.assert_allclose(np.asarray(kd_b), np.asarray(kd_f), atol=ATOL)
    np.testing.assert_allclose(np.asarray(vd_b), np.asarray(vd_f), atol=ATOL)


def test_prefill_then_decode_matches_full_forward(params):
    """The strongest L2 invariant: incremental decoding with the bifurcated
    kernel reproduces the logits of the full (non-incremental) forward pass
    on the growing sequence."""
    cfg, p = TINY, params[TINY.name]
    prompt_ids = [corpus.BOS] + corpus.encode("3+4=")
    ln = len(prompt_ids)
    toks, _ = corpus.prompt_tokens("3+4=", cfg.m_c_max)
    lg, kc, vc = M.prefill(p, cfg, jnp.asarray(toks), ln)

    # Full-forward oracle at the same position.
    full = jnp.asarray([prompt_ids], jnp.int32)
    lg_full, _, _ = M.forward_full(p, cfg, full, ln)
    np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(lg_full[0, ln - 1]),
                               atol=ATOL)

    # Decode three greedy tokens incrementally; compare each step's logits.
    b = 2  # two identical samplers — rows must agree with each other too
    kd = jnp.zeros((cfg.l, b, cfg.g, cfg.m_d_max, cfg.k))
    vd = jnp.zeros_like(kd)
    seq = list(prompt_ids)
    nxt = int(jnp.argmax(lg[0]))
    for step in range(3):
        toks_b = jnp.full((b,), nxt, jnp.int32)
        lg_step, kd, vd = M.decode_step(p, cfg, "bifurcated", toks_b, step, ln,
                                        kc, vc, kd, vd)
        seq.append(nxt)
        full = jnp.asarray([seq], jnp.int32)
        lg_full, _, _ = M.forward_full(p, cfg, full, len(seq))
        want = np.asarray(lg_full[0, len(seq) - 1])
        np.testing.assert_allclose(np.asarray(lg_step[0]), want, atol=ATOL)
        np.testing.assert_allclose(np.asarray(lg_step[1]), want, atol=ATOL)
        nxt = int(np.argmax(want))


def test_padded_batch_rows_independent(params):
    """Padding rows (engine pads to the bucket) must not alter real rows."""
    cfg, p = TINY, params[TINY.name]
    key = jax.random.PRNGKey(2)
    kc = jax.random.normal(key, (cfg.l, cfg.g, cfg.m_c_max, cfg.k)) * 0.3
    vc = jax.random.normal(key, (cfg.l, cfg.g, cfg.m_c_max, cfg.k)) * 0.3
    for b in (2, 4):
        kd = jnp.zeros((cfg.l, b, cfg.g, cfg.m_d_max, cfg.k))
        vd = jnp.zeros_like(kd)
        toks = jnp.array([5, 9] + [0] * (b - 2), jnp.int32)
        lg, _, _ = M.decode_step(p, cfg, "bifurcated", toks[:b], 0, 10, kc, vc, kd, vd)
        if b == 2:
            base = np.asarray(lg[:2])
        else:
            np.testing.assert_allclose(np.asarray(lg[:2]), base, atol=ATOL)


def test_loss_finite_and_reasonable(params):
    rng = np.random.default_rng(0)
    batch = corpus.training_batch(rng, 4, 32)
    loss = M.loss_fn(params[TINY.name], TINY, jnp.asarray(batch))
    assert np.isfinite(float(loss))
    # Untrained loss should be near ln(vocab)
    assert 1.5 < float(loss) < 4.0
