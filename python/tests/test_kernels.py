"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

The core exactness claim of the paper (Appendix E.1) is that bifurcated
attention computes the *identical* result to the fused baseline. We verify
it three ways, sweeping shapes/g/masks with hypothesis:

  oracle(fused) == oracle(bifurcated) == pallas(bifurcated) == pallas(fused)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bifurcated_decode, fused_decode
from compile.kernels import ref
from compile.kernels.bifurcated import hbm_traffic_bytes as bif_io
from compile.kernels.fused import hbm_traffic_bytes as fus_io

ATOL = 2e-5


def _rand_inputs(seed, b, g, p, k, mc, md):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(keys[0], (b, g, p, k), jnp.float32)
    kc = jax.random.normal(keys[1], (g, mc, k), jnp.float32)
    vc = jax.random.normal(keys[2], (g, mc, k), jnp.float32)
    kd = jax.random.normal(keys[3], (b, g, md, k), jnp.float32)
    vd = jax.random.normal(keys[4], (b, g, md, k), jnp.float32)
    return q, kc, vc, kd, vd


# strategy: h = g * p with small factors; mc/md small for interpret speed
shape_strategy = st.tuples(
    st.integers(1, 5),        # b
    st.integers(1, 4),        # g
    st.integers(1, 4),        # p  (h = g*p)
    st.sampled_from([4, 8, 16]),   # k
    st.integers(2, 24),       # mc
    st.integers(1, 8),        # md
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy, st.integers(0, 10_000), st.data())
def test_bifurcated_kernel_matches_oracle(shape, seed, data):
    b, g, p, k, mc, md = shape
    mlen = data.draw(st.integers(1, mc))
    dpos = data.draw(st.integers(0, md - 1))
    q, kc, vc, kd, vd = _rand_inputs(seed, b, g, p, k, mc, md)
    want = ref.decode_attention_ref(q, kc, vc, kd, vd, mlen, dpos)
    got = bifurcated_decode(q, kc, vc, kd, vd, mlen, dpos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(shape_strategy, st.integers(0, 10_000), st.data())
def test_fused_kernel_matches_oracle(shape, seed, data):
    b, g, p, k, mc, md = shape
    mlen = data.draw(st.integers(1, mc))
    dpos = data.draw(st.integers(0, md - 1))
    q, kc, vc, kd, vd = _rand_inputs(seed, b, g, p, k, mc, md)
    kcb = jnp.broadcast_to(kc[None], (b, g, mc, k))
    vcb = jnp.broadcast_to(vc[None], (b, g, mc, k))
    kfull = jnp.concatenate([kcb, kd], axis=2)
    vfull = jnp.concatenate([vcb, vd], axis=2)
    want = ref.decode_attention_ref(q, kc, vc, kd, vd, mlen, dpos)
    got = fused_decode(q, kfull, vfull, mlen, dpos, mc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


@settings(max_examples=40, deadline=None)
@given(shape_strategy, st.integers(0, 10_000), st.data())
def test_bifurcation_exactness_oracles(shape, seed, data):
    """Paper Appendix E.1: Eq. 3-4 == Eq. 1-2 exactly (up to fp assoc)."""
    b, g, p, k, mc, md = shape
    mlen = data.draw(st.integers(1, mc))
    dpos = data.draw(st.integers(0, md - 1))
    q, kc, vc, kd, vd = _rand_inputs(seed, b, g, p, k, mc, md)
    fused = ref.decode_attention_ref(q, kc, vc, kd, vd, mlen, dpos)
    bif = ref.bifurcated_decode_ref(q, kc, vc, kd, vd, mlen, dpos)
    np.testing.assert_allclose(np.asarray(bif), np.asarray(fused), atol=ATOL)


def test_multi_query_special_case():
    """g=1 (multi-query): all heads share one KV group."""
    q, kc, vc, kd, vd = _rand_inputs(3, b=4, g=1, p=8, k=8, mc=16, md=4)
    want = ref.decode_attention_ref(q, kc, vc, kd, vd, 12, 2)
    got = bifurcated_decode(q, kc, vc, kd, vd, 12, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_multi_head_special_case():
    """g=h (multi-head): p=1."""
    q, kc, vc, kd, vd = _rand_inputs(4, b=3, g=8, p=1, k=8, mc=16, md=4)
    want = ref.decode_attention_ref(q, kc, vc, kd, vd, 16, 3)
    got = bifurcated_decode(q, kc, vc, kd, vd, 16, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_batch_one_degenerate():
    """b=1: bifurcation is a no-op semantically."""
    q, kc, vc, kd, vd = _rand_inputs(5, b=1, g=2, p=2, k=8, mc=8, md=2)
    want = ref.decode_attention_ref(q, kc, vc, kd, vd, 8, 1)
    got = bifurcated_decode(q, kc, vc, kd, vd, 8, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_first_decode_step_mask():
    """d_pos=0: only the just-written decode slot is visible."""
    b, g, p, k, mc, md = 2, 2, 2, 8, 8, 4
    q, kc, vc, kd, vd = _rand_inputs(6, b, g, p, k, mc, md)
    # Poison invalid decode slots; they must not affect the result.
    kd_poison = kd.at[:, :, 1:].set(1e4)
    vd_poison = vd.at[:, :, 1:].set(1e4)
    a = bifurcated_decode(q, kc, vc, kd, vd, mc, 0)
    bp = bifurcated_decode(q, kc, vc, kd_poison, vd_poison, mc, 0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bp), atol=ATOL)


def test_context_mask_respects_mlen():
    """Positions >= m_c_len in the context cache must be ignored."""
    b, g, p, k, mc, md = 2, 2, 2, 8, 12, 4
    q, kc, vc, kd, vd = _rand_inputs(7, b, g, p, k, mc, md)
    mlen = 7
    kc_poison = kc.at[:, mlen:].set(-1e4)
    vc_poison = vc.at[:, mlen:].set(-1e4)
    a = bifurcated_decode(q, kc, vc, kd, vd, mlen, 1)
    bp = bifurcated_decode(q, kc_poison, vc_poison, kd, vd, mlen, 1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bp), atol=ATOL)


def test_jit_lowering_roundtrip():
    """The kernels lower under jit (the AOT path) and agree with eager."""
    q, kc, vc, kd, vd = _rand_inputs(8, b=2, g=2, p=2, k=8, mc=8, md=4)
    f = jax.jit(lambda *a: bifurcated_decode(*a, 8, 1))
    np.testing.assert_allclose(
        np.asarray(f(q, kc, vc, kd, vd)),
        np.asarray(bifurcated_decode(q, kc, vc, kd, vd, 8, 1)),
        atol=ATOL,
    )


@pytest.mark.parametrize("b", [1, 2, 8, 32])
def test_io_model_eq5_eq6(b):
    """The kernels' static IO accounting reproduces Eq. 5-6, and the
    bifurcated traffic is strictly smaller whenever b > 1."""
    g, k, mc, md = 4, 16, 64, 8
    fused = fus_io(b, g, k, mc, md)
    bif = bif_io(b, g, k, mc, md)
    assert fused == 4 * 2 * g * k * b * (mc + md)
    assert bif == 4 * 2 * g * k * (mc + b * md)
    if b == 1:
        assert bif == fused
    else:
        assert bif < fused


def test_bf16_inputs():
    """bf16 KV (the paper's serving dtype) stays within loose tolerance."""
    q, kc, vc, kd, vd = _rand_inputs(9, b=2, g=2, p=2, k=8, mc=8, md=4)
    cast = lambda x: x.astype(jnp.bfloat16)
    got = bifurcated_decode(cast(q), cast(kc), cast(vc), cast(kd), cast(vd), 8, 1)
    want = ref.decode_attention_ref(q, kc, vc, kd, vd, 8, 1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=0.05
    )
