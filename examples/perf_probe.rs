//! §Perf probe (EXPERIMENTS.md §Perf): quantifies the engine hot-path
//! optimizations, before/after:
//! (1) weights resident on device (`execute_b`) vs re-uploaded per step
//!     (execute with literals) — the baseline the runtime started from;
//! (2) shared-context residency vs per-step context upload.
//!
//!     cargo run --release --offline --example perf_probe

use bifurcated_attn::bench::Bencher;
use bifurcated_attn::runtime::client::{run_buffers, run_tensors, upload};
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::tensor::{load_weights_bin, HostTensor};
use bifurcated_attn::runtime::{cpu_client, Manifest, ModelRuntime};

fn main() -> anyhow::Result<()> {
    let man = Manifest::load(&Manifest::default_root())?;
    let client = cpu_client()?;
    let rt = ModelRuntime::load(&man, &client, "pico-mh")?;
    let b = 8usize;
    rt.warm(&[DecodeMode::Bifurcated], &[b])?;
    let entry = man.serving_entry("pico-mh")?;
    let weights = load_weights_bin(&entry.weights_bin, &entry.param_spec)?;

    let mut prompt = vec![man.tokenizer.bos];
    prompt.extend(man.tokenizer.encode("10+2=12;11+3=14;12+4=")?);
    let pre = rt.prefill(&prompt)?;
    let ctx = rt.upload_context(&pre.kc, &pre.vc, prompt.len())?;
    let (kd, vd) = rt.zero_decode_cache(b);
    let toks = vec![3i32; b];

    let bench = Bencher::new("perf");
    // AFTER (current engine path): weights + context resident
    let s_resident = bench.run(|| {
        rt.decode(DecodeMode::Bifurcated, b, &toks, 0, &ctx, &kd, &vd).unwrap();
    });

    // BEFORE: every input re-uploaded per step via literals (weights incl.)
    let exe = rt.decode_exe(DecodeMode::Bifurcated, b)?;
    let tok_t = HostTensor::from_i32(toks.clone(), &[b]);
    let pos_t = HostTensor::scalar_i32(0);
    let len_t = HostTensor::scalar_i32(prompt.len() as i32);
    let s_literals = bench.run(|| {
        let mut inputs: Vec<&HostTensor> = weights.iter().collect();
        inputs.extend([&tok_t, &pos_t, &len_t, &pre.kc, &pre.vc, &kd, &vd]);
        run_tensors(&exe, &inputs).unwrap();
    });

    // MIDDLE: weights resident, context re-uploaded each step
    let weight_bufs: Vec<_> = weights.iter().map(|t| upload(&client, t).unwrap()).collect();
    let s_ctx_upload = bench.run(|| {
        let kc_buf = upload(&client, &pre.kc).unwrap();
        let vc_buf = upload(&client, &pre.vc).unwrap();
        let tok_buf = upload(&client, &tok_t).unwrap();
        let pos_buf = upload(&client, &pos_t).unwrap();
        let len_buf = upload(&client, &len_t).unwrap();
        let kd_buf = upload(&client, &kd).unwrap();
        let vd_buf = upload(&client, &vd).unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = weight_bufs.iter().collect();
        inputs.extend([&tok_buf, &pos_buf, &len_buf, &kc_buf, &vc_buf, &kd_buf, &vd_buf]);
        run_buffers(&exe, &inputs).unwrap();
    });

    println!("decode step b={b} (pico-mh, bifurcated):");
    println!("  all-literals per step (naive)        p50 = {:.3} ms", s_literals.p50);
    println!("  weights resident, ctx re-uploaded    p50 = {:.3} ms", s_ctx_upload.p50);
    println!("  weights + context resident (engine)  p50 = {:.3} ms", s_resident.p50);
    Ok(())
}
