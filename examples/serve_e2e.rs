//! End-to-end serving driver (the repo's E2E validation run): start the
//! HTTP server on the engine event loop, fire concurrent generate
//! requests from client threads, and report latency/throughput. Recorded
//! in EXPERIMENTS.md.
//!
//!     cargo run --release --offline --example serve_e2e [--requests 12] [--n 8]

use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use bifurcated_attn::coordinator::EngineConfig;
use bifurcated_attn::runtime::Manifest;
use bifurcated_attn::util::cli::Args;
use bifurcated_attn::util::histogram::Histogram;
use bifurcated_attn::util::prng::Pcg;

fn http_post(addr: &str, path: &str, body: &str) -> anyhow::Result<String> {
    let mut stream = std::net::TcpStream::connect(addr)?;
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: e2e\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    anyhow::ensure!(resp.starts_with("HTTP/1.1 200"), "bad response: {resp}");
    Ok(resp.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 12);
    let n_samples = args.usize_or("n", 8);
    let addr = "127.0.0.1:8093";

    // leader: engine event loop + HTTP front-end
    let client = bifurcated_attn::server::spawn_engine(
        Manifest::default_root(),
        args.str_or("model", "pico-mq"),
        EngineConfig::default(),
    )?;
    let server = bifurcated_attn::server::build_server(client);
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&shutdown);
    let server_thread = std::thread::spawn(move || server.serve(addr, 4, Some(flag)).unwrap());
    std::thread::sleep(std::time::Duration::from_millis(300));

    // workload: concurrent clients, each asking n parallel samples for a
    // random arithmetic task (one shared prefix per request)
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for i in 0..n_requests {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(f64, bool)> {
            let mut rng = Pcg::new(1000 + i as u64);
            let task = bifurcated_attn::corpus::make_task(&mut rng, 3);
            let body = format!(
                r#"{{"prompt":"{}","n":{n_samples},"rerank_top_k":3,"seed":{i}}}"#,
                task.prompt
            );
            let t = Instant::now();
            let resp = http_post(&addr, "/generate", &body)?;
            let ms = t.elapsed().as_secs_f64() * 1e3;
            let doc = bifurcated_attn::util::json::parse(&resp)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let top_correct = doc
                .req("reranked")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .any(|c| task.check(&c.str_or("text", "")));
            Ok((ms, top_correct))
        }));
    }
    let mut hist = Histogram::new();
    let mut correct = 0usize;
    for h in handles {
        let (ms, ok) = h.join().unwrap()?;
        hist.record(ms);
        if ok {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = hist.summary();
    println!(
        "{n_requests} requests x {n_samples} samples in {wall:.1}s  ({:.2} req/s, {:.1} completions/s)",
        n_requests as f64 / wall,
        (n_requests * n_samples) as f64 / wall
    );
    println!(
        "request latency ms: p50={:.0} p90={:.0} max={:.0}   top3-contains-answer: {}/{}",
        s.p50, s.p90, s.max, correct, n_requests
    );

    shutdown.store(true, Ordering::SeqCst);
    server_thread.join().unwrap();
    Ok(())
}
