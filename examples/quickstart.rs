//! Quickstart: load a trained pico model through the AOT artifacts, sample
//! 8 parallel completions from one shared prompt, rerank by mean log-p.
//!
//!     make artifacts && cargo run --release --offline --example quickstart

use bifurcated_attn::coordinator::{
    rerank_top_k, Engine, EngineConfig, GenerationRequest, SamplingParams,
};
use bifurcated_attn::corpus;
use bifurcated_attn::runtime::{cpu_client, Manifest, ModelRuntime};

fn main() -> anyhow::Result<()> {
    // 1. artifacts -> runtime -> engine
    let manifest = Manifest::load(&Manifest::default_root())?;
    let client = cpu_client()?;
    let rt = ModelRuntime::load(&manifest, &client, "pico-mq")?;
    let engine = Engine::new(&manifest, rt, EngineConfig::default());

    // 2. one shared context, n parallel samplers (single-context batch
    //    sampling — the paper's Fig. 1 right panel)
    let request = GenerationRequest {
        id: 1,
        prompt: "10+2=12;11+3=14;7+8=".into(),
        params: SamplingParams {
            n: 8,
            temperature: 0.8,
            top_p: 0.95,
            max_tokens: 6,
            stop_token: Some(corpus::SEMI),
            seed: 0,
            mode: None,
        },
    };
    let result = engine.generate(&request)?;

    println!(
        "mode={}  prefill {:.1} ms, {} decode steps at {:.1} ms/step",
        result.mode_used,
        result.timing.prefill_ms,
        result.timing.decode_steps,
        result.timing.per_step_ms()
    );
    for (i, c) in result.completions.iter().enumerate() {
        let ok = if c.text.starts_with("15;") { "✓" } else { " " };
        println!("  sample {i}: {:8} mean_logp={:+.3} {}", c.text, c.mean_logp(), ok);
    }

    // 3. mean-log-p reranking (the paper's pass@top3 selection)
    let top3 = rerank_top_k(&result.completions, 3);
    println!(
        "top-3 by mean log-p: {:?}",
        top3.iter().map(|c| c.text.as_str()).collect::<Vec<_>>()
    );
    Ok(())
}
