//! Parallel-sampling latency demo (the paper's headline experiment at pico
//! scale, measured for real on CPU PJRT): sweep the batch size with the
//! fused baseline vs bifurcated attention and print per-step latency and
//! host->device context traffic (Eq. 5 vs Eq. 6).
//!
//!     cargo run --release --offline --example parallel_sampling [--quick]

use bifurcated_attn::bench::{Bencher, Cell, Table};
use bifurcated_attn::runtime::models::DecodeMode;
use bifurcated_attn::runtime::{cpu_client, Manifest, ModelRuntime};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let manifest = Manifest::load(&Manifest::default_root())?;
    let client = cpu_client()?;
    let rt = ModelRuntime::load(&manifest, &client, "pico-mh")?;
    let buckets: &[usize] = if quick { &[1, 8] } else { &[1, 2, 4, 8, 16, 32] };
    rt.warm(&[DecodeMode::Bifurcated, DecodeMode::Fused], buckets)?;

    // a long-ish shared prefix so K_c dominates (m_c ~ 42 of 96)
    let mut prompt = vec![manifest.tokenizer.bos];
    prompt.extend(
        manifest
            .tokenizer
            .encode("10+2=12;11+3=14;12+4=16;13+5=18;14+6=20;1+2=")?,
    );
    let pre = rt.prefill(&prompt)?;

    let mut t = Table::new(
        "Parallel sampling: per-step decode latency vs batch (pico-mh, measured)",
        &["b", "fused ms", "bifurcated ms", "speedup", "ctx upload fused", "ctx upload bif"],
    );
    for &b in buckets {
        let bench = if quick { Bencher::quick("d") } else { Bencher::new("d") };
        let ctx_bif = rt.upload_context(&pre.kc, &pre.vc, prompt.len())?;
        let ctx_fus = rt.upload_context(
            &pre.kc.broadcast_at(1, b),
            &pre.vc.broadcast_at(1, b),
            prompt.len(),
        )?;
        let (kd, vd) = rt.zero_decode_cache(b);
        let toks = vec![3i32; b];
        let f = bench
            .run(|| {
                rt.decode(DecodeMode::Fused, b, &toks, 0, &ctx_fus, &kd, &vd).unwrap();
            })
            .p50;
        let s = bench
            .run(|| {
                rt.decode(DecodeMode::Bifurcated, b, &toks, 0, &ctx_bif, &kd, &vd).unwrap();
            })
            .p50;
        t.row(vec![
            Cell::Num(b as f64),
            Cell::Ms(f),
            Cell::Ms(s),
            Cell::Num((f / s * 100.0).round() / 100.0),
            Cell::Num(ctx_fus.bytes as f64),
            Cell::Num(ctx_bif.bytes as f64),
        ]);
    }
    t.print();
    println!("\n(the fused column's context upload grows ~b x; bifurcated stays constant —");
    println!(" that is Eq. 5 vs Eq. 6 measured across the PJRT boundary)");
    Ok(())
}
