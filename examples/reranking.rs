//! Reranking demo (paper Sec. 5.4): run the checkable task suite at
//! several sample counts; show pass@1 / pass@n / pass@top3 rising with n
//! while latency stays ~flat thanks to shared-prefix batch decoding.
//!
//!     cargo run --release --offline --example reranking [--quick]

use bifurcated_attn::bench::{Cell, Table};
use bifurcated_attn::coordinator::{Engine, EngineConfig};
use bifurcated_attn::evalharness::{run_suite, SuiteConfig};
use bifurcated_attn::runtime::{cpu_client, Manifest, ModelRuntime};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let manifest = Manifest::load(&Manifest::default_root())?;
    let client = cpu_client()?;
    let rt = ModelRuntime::load(&manifest, &client, "pico-mq")?;
    let engine = Engine::new(&manifest, rt, EngineConfig::default());

    let ns: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8, 16] };
    let mut t = Table::new(
        "pass@n / pass@top3 vs measured latency (pico-mq)",
        &["n", "pass@1", "pass@n", "pass@top3", "latency ms"],
    );
    for &n in ns {
        let res = run_suite(
            &engine,
            &SuiteConfig {
                n_tasks: if quick { 5 } else { 12 },
                n_samples: n,
                seed: 21,
                ..Default::default()
            },
        )?;
        t.row(vec![
            Cell::Num(n as f64),
            Cell::Num((res.pass_at[0] * 100.0).round() / 100.0),
            Cell::Num((res.pass_at[n - 1] * 100.0).round() / 100.0),
            Cell::Num((res.pass_top3 * 100.0).round() / 100.0),
            Cell::Ms(res.mean_latency_ms),
        ]);
    }
    t.print();
    Ok(())
}
